//! The fleet loop: a deterministic multi-job simulation of one mesh
//! shared by many training jobs under a failure/repair process.
//!
//! Two clock engines share one fleet state machine
//! ([`FleetConfig::clock`]):
//!
//! - [`ClockMode::RoundRobin`] — the differential reference. Time
//!   advances in integer *fleet steps*; each running job trains at
//!   `rate = compute_s / step_s(shape, holes)` job-steps per fleet
//!   step, where `step_s` is the DES-simulated fault-tolerant
//!   allreduce on the job's sub-mesh plus the modelled compute.
//! - [`ClockMode::WallClock`] — the event-driven engine. Cluster
//!   events and job arrivals merge into one globally time-sorted
//!   timeline, drained in same-instant batches with a cursor (the
//!   timeline is fixed up front, so no heap is needed) on a
//!   continuous `f64` clock; between events each job
//!   integrates progress at its own effective rate, with pauses
//!   consumed continuously. Progress integration splits at integer
//!   fleet-step boundaries — the grid utilization/goodput/queue-wait
//!   metrics are defined on — which is what makes the contention-off
//!   wall-clock engine reproduce the round-robin fleet **bit for
//!   bit** (the differential contract `rust/tests/fleet_async.rs`
//!   enforces). With [`FleetConfig::contention`] enabled the engine
//!   is genuinely asynchronous: job completions cut segments at
//!   fractional times, and every reconfiguration starts a new *link
//!   epoch* in which [`contention`] re-splits per-edge occupancy
//!   max-min fairly, dilating the step times of jobs whose allreduce
//!   rings meet on shared or adjacent mesh edges.
//!
//! All step-time predictions flow through **one process-wide plan
//! cache** shared by every job: equal shapes hit each other's compiled
//! plans, and a migrated job warm-starts from the plans its previous
//! placement compiled.
//!
//! Determinism: the workload, the MTBF timeline and every decision are
//! pure functions of the config (transition costs are modelled in
//! steps, never measured wall time), so two runs with equal configs
//! agree bit-for-bit — the property the per-policy goodput comparison
//! relies on.

use super::contention::{self, ContentionModel};
use super::metrics::{
    mean_median, FleetProfile, FleetRun, FleetSummary, JobOutcome, LinkHotspot, UtilSample,
};
use super::placer::{self, Rect};
use super::workload::WorkloadModel;
use super::{FleetError, JobClass, JobPolicy, JobSpec};
use crate::cluster::{ClusterEvent, ClusterState, EventQueue, MtbfModel, TimedEvent};
use crate::collective::{PlanCache, PlanCacheStats, PlanError, Scheme};
use crate::coordinator::policy::{effective_throughput, CandidateCost, EventRateEstimator};
use crate::mesh::{heal, FailedRegion, LinkRemap, Mesh, Topology};
use crate::obs::{Registry, STEP_US};
use crate::perfmodel::steptime;
use crate::perfmodel::{CandidatePrediction, RecoveryPhases};
use crate::simnet::{simulate_plan, simulate_plan_remapped, LinkModel};
use std::collections::{HashMap, VecDeque};
use std::time::Instant;

/// Which time model drives the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockMode {
    /// Integer fleet steps, one global clock (the legacy engine and
    /// the differential reference).
    RoundRobin,
    /// Event-driven continuous timeline with per-job rates and
    /// optional cross-job link contention.
    WallClock,
}

impl ClockMode {
    pub const ALL: [ClockMode; 2] = [ClockMode::RoundRobin, ClockMode::WallClock];

    pub fn name(&self) -> &'static str {
        match self {
            ClockMode::RoundRobin => "round-robin",
            ClockMode::WallClock => "wall-clock",
        }
    }

    /// Parse a CLI spelling (`rr`, `round-robin`, `wall`,
    /// `wall-clock`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rr" | "round-robin" => Some(ClockMode::RoundRobin),
            "wall" | "wall-clock" => Some(ClockMode::WallClock),
            _ => None,
        }
    }
}

/// Fleet configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub nx: usize,
    pub ny: usize,
    /// Fleet horizon in fleet steps.
    pub horizon: u64,
    pub workload: WorkloadModel,
    /// Seeded failure/repair process (`None` = only `events`).
    pub mtbf: Option<MtbfModel>,
    /// Scripted extra events (merged with the MTBF timeline).
    pub events: Vec<TimedEvent>,
    /// Override every job's recovery policy (per-policy comparison);
    /// `None` keeps the per-job policies from the workload.
    pub policy: Option<JobPolicy>,
    /// Gradient payload per job, f32 elements.
    pub payload: usize,
    /// Modelled per-worker compute seconds per training step.
    pub compute_s: f64,
    /// Implicit checkpoint cadence (job steps); restarts roll back to
    /// the last multiple.
    pub checkpoint_every: u64,
    /// Modelled pause (fleet steps) for a fault-tolerant ring rebuild.
    pub rebuild_steps: f64,
    /// Modelled pause (fleet steps) for any restart.
    pub restart_steps: f64,
    /// Extra pause (fleet steps) for moving to a different rectangle.
    pub migrate_steps: f64,
    /// Plan-cache capacity (shared by all jobs).
    pub cache_cap: usize,
    /// Verify every cache hit / incremental compile against a fresh
    /// full compile (CI gate; fails the run on divergence).
    pub verify: bool,
    /// Warm-start cache (e.g. loaded from a plan-cache file).
    pub seed_cache: Option<PlanCache>,
    /// Time model (see [`ClockMode`]).
    pub clock: ClockMode,
    /// Cross-job link contention (wall-clock engine only; `None`
    /// disables the accounting entirely).
    pub contention: Option<ContentionModel>,
    /// Sparse-occupancy fast paths for the contention engine:
    /// per-placement link-load memoization, epoch-to-epoch skips when
    /// the placement signature is unchanged, and touched-slot hotspot
    /// extraction. `false` forces the dense full-recompute reference
    /// path; both are bit-identical
    /// (`rust/tests/scale_equivalence.rs`).
    pub sparse_occupancy: bool,
    /// Admit later queued jobs around a blocked FIFO head. Safe by
    /// construction: backfill only runs when the head is unplaceable,
    /// and obstacles only grow as backfills commit, so no backfilled
    /// start precedes a feasible head placement it could have blocked.
    pub backfill: bool,
    /// Incremental placement index ([`placer::PlacementIndex`]):
    /// maintain the obstacle strips across place/free/fail/repair and
    /// answer placement queries in O(affected strips) instead of a full
    /// mesh rescan. `false` forces the dense scan reference path; both
    /// are bit-identical (`rust/tests/fleet_placement.rs`).
    pub fast_placer: bool,
    /// Spare physical rows provisioned beyond the logical `nx x ny`
    /// mesh for reconfigurable-mesh healing ([`crate::mesh::heal`]).
    /// The physical mesh failures are sampled on is
    /// `(nx + spare_cols) x (ny + spare_rows)`; jobs place on the
    /// logical mesh only. `0, 0` (the default) disables healing and
    /// reproduces the unspared fleet bit-for-bit.
    pub spare_rows: usize,
    /// Spare physical columns (see [`Self::spare_rows`]).
    pub spare_cols: usize,
    /// One-off pause (fleet steps) charged to every running job when a
    /// heal changes the adopted link remap: bypass switches flip and
    /// chips newly mapped into the logical mesh copy parameters from a
    /// live data-parallel peer (no rollback — replicas survive).
    pub rewire_steps: f64,
    /// Structured tracer sink (`--trace`). The tracer is a write-only
    /// observer stamped with sim time: `None` (the default) costs one
    /// branch per hook, and `Some` never perturbs the simulation —
    /// trace-on and trace-off runs are bit-identical
    /// (`rust/tests/obs_differential.rs`).
    pub trace: Option<crate::obs::TraceHandle>,
    /// Let queued serving jobs preempt training placements
    /// (checkpoint, evict, re-place via the migrate path) when no
    /// rectangle is clear. `false` leaves serving jobs queueing like
    /// everyone else. Irrelevant — and bit-invisible — without serving
    /// jobs in the workload.
    pub serving_preemption: bool,
}

impl FleetConfig {
    /// The acceptance-scale fleet: 16x32 mesh (512 chips), 8 jobs,
    /// host-shaped failures with repairs.
    pub fn paper_scale() -> Self {
        Self {
            nx: 16,
            ny: 32,
            horizon: 2000,
            workload: WorkloadModel::paper_scale(1),
            mtbf: Some(MtbfModel::host(11, 250.0, 120.0)),
            events: Vec::new(),
            policy: None,
            payload: 1 << 20,
            compute_s: 0.05,
            checkpoint_every: 50,
            rebuild_steps: 1.0,
            restart_steps: 5.0,
            migrate_steps: 3.0,
            cache_cap: 64,
            verify: false,
            seed_cache: None,
            clock: ClockMode::RoundRobin,
            contention: None,
            sparse_occupancy: true,
            backfill: false,
            fast_placer: true,
            spare_rows: 0,
            spare_cols: 0,
            rewire_steps: 10.0,
            trace: None,
            serving_preemption: true,
        }
    }

    /// Reduced fleet for CI: same 16x32 mesh and ≥4 concurrent jobs,
    /// shorter horizon and smaller payload.
    pub fn quick() -> Self {
        Self {
            nx: 16,
            ny: 32,
            horizon: 400,
            workload: WorkloadModel::quick(1),
            mtbf: Some(MtbfModel::board(7, 60.0, 30.0)),
            events: Vec::new(),
            policy: None,
            payload: 1 << 14,
            compute_s: 0.02,
            checkpoint_every: 20,
            rebuild_steps: 1.0,
            restart_steps: 5.0,
            migrate_steps: 3.0,
            cache_cap: 64,
            verify: false,
            seed_cache: None,
            clock: ClockMode::RoundRobin,
            contention: None,
            sparse_occupancy: true,
            backfill: false,
            fast_placer: true,
            spare_rows: 0,
            spare_cols: 0,
            rewire_steps: 10.0,
            trace: None,
            serving_preemption: true,
        }
    }

    /// Physical mesh dimensions: the logical mesh plus provisioned
    /// spares.
    pub fn phys_dims(&self) -> (usize, usize) {
        (self.nx + self.spare_cols, self.ny + self.spare_rows)
    }

    /// Are spare rows/columns provisioned (healing enabled)?
    pub fn has_spares(&self) -> bool {
        self.spare_rows + self.spare_cols > 0
    }
}

/// A recovery action the fleet can apply to one job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Action {
    Ft,
    Shrink,
    Migrate,
    Wait,
}

impl Action {
    fn name(self) -> &'static str {
        match self {
            Action::Ft => "continue-ft",
            Action::Shrink => "shrink",
            Action::Migrate => "migrate",
            Action::Wait => "queue-wait",
        }
    }
}

/// The restart family of actions, sharing one application path.
#[derive(Debug, Clone, Copy)]
enum RestartKind {
    Shrink,
    Migrate,
}

/// Sentinel latency (ms) charged to serving requests that arrive while
/// their job holds no rectangle: the request waits the outage out, far
/// past any plausible SLO threshold. Keeping evicted serving jobs
/// accountable for their offered load is what makes the
/// preemption-on-vs-off SLO comparison meaningful.
const SERVING_DOWN_MS: f64 = 1e6;

/// Request intensity (requests per fleet step) at integer step `t`;
/// 0.0 when no request process is configured.
fn intensity_at(intensity: &[f64], t: u64) -> f64 {
    if intensity.is_empty() {
        return 0.0;
    }
    intensity[(t as usize).min(intensity.len() - 1)]
}

/// Serving-latency accounting for one integration segment of a
/// *placed* serving job. The `dt`-long segment offers `lam * dt`
/// requests: the active fraction `frac` is served at the M/D/1 queue
/// latency of the job's current (possibly contention-dilated) step
/// time, and the paused remainder additionally waits the transition
/// pause out. Parcels are `(request weight, latency ms)`; identical
/// arithmetic under both clock engines (`dt == 1.0`, dilation 1.0
/// reproduces the round-robin figures bit for bit).
fn serve_segment(
    j: &mut Job,
    compute_s: f64,
    lam: f64,
    dt: f64,
    frac: f64,
    pause_before: f64,
    parcels: &mut Vec<(f64, f64)>,
) {
    if lam <= 0.0 {
        return;
    }
    let thr = j.spec.slo.map(|s| s.threshold_ms).unwrap_or(f64::INFINITY);
    let lat_ms = if j.rate > 0.0 {
        let step_s = compute_s / j.rate;
        let rho = lam * j.dilation / j.rate;
        steptime::serving_latency_ms(step_s, j.dilation, rho)
    } else {
        SERVING_DOWN_MS
    };
    let active = lam * frac;
    if active > 0.0 {
        j.requests += active;
        if lat_ms <= thr {
            j.slo_met += active;
        }
        parcels.push((active, lat_ms));
    }
    let paused = lam * (dt - frac);
    if paused > 0.0 {
        // One fleet step spans `compute_s` seconds of wall time (a
        // healthy job completes `rate` steps of `step_s` seconds
        // each), so the pause converts at that scale.
        let wait_ms = pause_before * compute_s * 1e3 + lat_ms;
        j.requests += paused;
        if wait_ms <= thr {
            j.slo_met += paused;
        }
        parcels.push((paused, wait_ms));
    }
}

/// A queued serving job (evicted, or not yet placeable) still receives
/// its offered load; every request waits the outage out at the
/// [`SERVING_DOWN_MS`] sentinel and misses any finite SLO.
fn queued_segment(j: &mut Job, lam: f64, dt: f64, parcels: &mut Vec<(f64, f64)>) {
    let offered = lam * dt;
    if offered > 0.0 {
        j.requests += offered;
        parcels.push((offered, SERVING_DOWN_MS));
    }
}

/// Request-weighted percentile over `(weight, latency ms)` parcels;
/// 0.0 with no traffic. Sorts by latency and walks the cumulative
/// weight to `q` of the total — exact for the piecewise-constant
/// parcel distribution, and deterministic (`total_cmp`).
fn weighted_latency_percentile(parcels: &mut [(f64, f64)], q: f64) -> f64 {
    if parcels.is_empty() {
        return 0.0;
    }
    parcels.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.total_cmp(&b.0)));
    let total: f64 = parcels.iter().map(|p| p.0).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let target = q * total;
    let mut acc = 0.0;
    for &(w, lat) in parcels.iter() {
        acc += w;
        if acc >= target {
            return lat;
        }
    }
    parcels.last().map(|p| p.1).unwrap_or(0.0)
}

/// One arrival's event-log line. Serving jobs have no finite duration
/// to print (they run to the horizon); training keeps the exact
/// pre-serving wording, so serving-free event logs are unchanged.
fn arrival_message(spec: &JobSpec) -> String {
    match spec.class {
        JobClass::Training => format!(
            "job {} arrives: {}x{} for {} steps ({})",
            spec.id,
            spec.w,
            spec.h,
            spec.duration_steps,
            spec.policy.name()
        ),
        JobClass::Serving => format!(
            "serving job {} arrives: {}x{} ({})",
            spec.id,
            spec.w,
            spec.h,
            spec.policy.name()
        ),
    }
}

#[derive(Debug, Clone)]
struct Job {
    spec: JobSpec,
    /// Allocated rectangle (cluster coords); `None` while queued.
    rect: Option<Rect>,
    /// Live failed regions clipped to `rect` (cluster coords).
    holes: Vec<Rect>,
    /// Completed training steps (fractional).
    progress: f64,
    /// Job steps per fleet step on the current placement, isolated.
    rate: f64,
    /// Cross-job contention dilation of the current link epoch
    /// (>= 1.0; the effective rate is `rate / dilation`).
    dilation: f64,
    workers: usize,
    /// Remaining transition pause, fleet steps.
    pause: f64,
    started: bool,
    completed_at: Option<u64>,
    waited: u64,
    migrations: u64,
    shrinks: u64,
    ft_continues: u64,
    /// Offered serving requests integrated over the run (0.0 for
    /// training jobs).
    requests: f64,
    /// Offered requests answered within the job's SLO threshold.
    slo_met: f64,
}

impl Job {
    fn new(spec: JobSpec) -> Self {
        Self {
            spec,
            rect: None,
            holes: Vec::new(),
            progress: 0.0,
            rate: 0.0,
            dilation: 1.0,
            workers: 0,
            pause: 0.0,
            started: false,
            completed_at: None,
            waited: 0,
            migrations: 0,
            shrinks: 0,
            ft_continues: 0,
            requests: 0.0,
            slo_met: 0.0,
        }
    }

    fn outcome(&self) -> JobOutcome {
        JobOutcome {
            id: self.spec.id,
            w: self.spec.w,
            h: self.spec.h,
            policy: self.spec.policy,
            class: self.spec.class,
            arrival_step: self.spec.arrival_step,
            completed_at: self.completed_at,
            migrations: self.migrations,
            shrinks: self.shrinks,
            ft_continues: self.ft_continues,
            waited_steps: self.waited,
            requests: self.requests,
            slo_met: self.slo_met,
        }
    }
}

/// One memoized sub-mesh simulation: step time plus the per-link busy
/// seconds the contention accounting charges.
#[derive(Debug, Clone)]
struct StepSim {
    step_s: f64,
    /// `(local dense link slot, busy seconds)` of one allreduce.
    busy: Vec<(usize, f64)>,
}

/// Sub-mesh simulation memo key: `(w, h, sorted local holes, link
/// spans)`. The span vector is the job rectangle's slice of the global
/// link remap (empty for the identity remap), so equal shapes under
/// different heals simulate — and memoize — separately.
type SimKey = (usize, usize, Vec<Rect>, Vec<u32>);

/// Link-load memo key: the sub-mesh simulation key plus the
/// rectangle's cluster origin. `contention::job_load` is a pure
/// function of exactly these inputs (the busy vector and step time
/// come from the immutable sim memo entry for the same key), so
/// entries never need invalidation — a moved or reshaped job simply
/// reads a different key.
type LoadKey = (SimKey, usize, usize);

/// One link epoch's placement signature: per running job (in order)
/// its rectangle, sub-mesh sim key, schedulability, and paused flag —
/// every input the fair-share split depends on. Equal signatures imply
/// bit-identical epoch outputs.
type EpochSig = Vec<(Rect, SimKey, bool, bool)>;

struct Fleet<'a> {
    cfg: &'a FleetConfig,
    /// The **logical** cluster ledger jobs place on: with spares
    /// provisioned it holds the visible images of physical failures
    /// under the adopted remap, otherwise the physical failures
    /// themselves.
    cluster: ClusterState,
    /// The physical ledger (logical mesh + provisioned spares);
    /// `None` when no spares are provisioned.
    phys: Option<ClusterState>,
    /// Adopted logical-to-physical link remap (identity prefix until a
    /// heal is adopted; always the identity with no spares).
    remap: LinkRemap,
    /// Heals adopted (remap changes), each pausing every running job
    /// for `FleetConfig::rewire_steps`.
    rewires: u64,
    cache: PlanCache,
    /// Step-time memo per (w, h, sorted local holes): each distinct
    /// sub-mesh topology is simulated once.
    sim_memo: HashMap<SimKey, StepSim>,
    /// Cluster-level link-load memo (sparse-occupancy path): one
    /// [`contention::job_load`] translation per distinct (sub-mesh,
    /// origin) placement, reused across link epochs.
    load_memo: HashMap<LoadKey, contention::JobLoad>,
    /// Plan-cache counters at construction; [`FleetSummary::cache`]
    /// reports the delta so runs sharing a seed cache (or a warm-start
    /// file) record only their own traffic.
    stats_base: PlanCacheStats,
    link: LinkModel,
    estimator: EventRateEstimator,
    queue: VecDeque<Job>,
    running: Vec<Job>,
    done: Vec<Job>,
    step: u64,
    /// Wall-clock engine's continuous time, fleet-step units.
    now: f64,
    sample_every: u64,
    transitions: u64,
    queue_waits: u64,
    backfills: u64,
    goodput_sum: f64,
    util_sum: f64,
    last_util: f64,
    last_good: f64,
    /// Within-step accumulators (wall-clock engine; flushed at every
    /// integer boundary so the op sequence matches round-robin).
    step_util_acc: f64,
    step_good_acc: f64,
    /// Contention bookkeeping.
    contention_epochs: u64,
    dilation_time: f64,
    dilation_weight: f64,
    max_dilation: f64,
    /// Current epoch's charged occupancy per cluster link slot.
    epoch_charge: Vec<(usize, f64)>,
    /// Placement signature of the last fully computed link epoch,
    /// with its granted dilations and diagnostic figures — the
    /// unchanged-placement skip replays these instead of re-splitting.
    last_epoch_sig: Option<EpochSig>,
    last_epoch_dil: Vec<f64>,
    last_epoch_max: f64,
    last_epoch_share: f64,
    /// Time-integrated charged occupancy per cluster link slot.
    link_occ: Vec<f64>,
    /// Slots ever charged into `link_occ`, first-touch order (may hold
    /// duplicates when a zero-magnitude charge precedes a real one;
    /// deduplicated at extraction).
    occ_touched: Vec<u32>,
    /// Integration segments processed (round-robin steps or wall-clock
    /// segments) — the events/sec denominator `BENCH_scale.json`
    /// reports against.
    segments: u64,
    samples: Vec<UtilSample>,
    events_log: Vec<(u64, String)>,
    /// Incremental placement index (`FleetConfig::fast_placer`); kept
    /// in lockstep with failed regions + running rectangles and
    /// cross-checked by `check_invariants`.
    pidx: Option<placer::PlacementIndex>,
    /// Per-phase wall-time accumulators (`FleetRun::profile`). Never
    /// read by the simulation, so profiling cannot perturb determinism.
    prof: FleetProfile,
    /// Trace process track for this run (0 until the driver allocates
    /// one; only meaningful when `cfg.trace` is `Some`).
    pid: u32,
    /// Typed metrics registry ([`FleetRun::metrics`]): recovery-latency
    /// histograms, DES/contention counters, hotspot-truncation counts.
    /// Write-only during the run, like `prof`.
    reg: Registry,
    /// Contended-edge count of the last fully computed link epoch,
    /// replayed (like the dilations) on the unchanged-placement skip
    /// path so sparse and dense runs record identical counters.
    last_epoch_contended: u64,
    /// Does the generated workload contain serving jobs? Set by the
    /// engines before the first event; every serving-only code path is
    /// gated on it (or on per-job class checks that cannot fire
    /// without serving jobs), so a serving-free fleet is bit-identical
    /// to the pre-serving engine.
    has_serving: bool,
    /// Rendered request intensity per fleet step (empty without a
    /// configured [`super::workload::RequestProcess`]).
    serving_intensity: Vec<f64>,
    /// Request-weighted latency parcels `(requests, latency ms)`, in
    /// deterministic emission order — the summary p99 source.
    serving_lat: Vec<(f64, f64)>,
    /// Training placements evicted for serving rectangles.
    preemptions: u64,
}

impl<'a> Fleet<'a> {
    fn new(cfg: &'a FleetConfig) -> Self {
        let mut cache = match &cfg.seed_cache {
            Some(seed) => seed.clone(),
            None => PlanCache::new(cfg.cache_cap),
        };
        cache.set_verification(cfg.verify);
        // A seed cache cloned from an earlier run may carry that run's
        // trace sink; each engine re-attaches under its own pid.
        cache.set_trace(None, 0);
        let stats_base = cache.stats().clone();
        let (pnx, pny) = cfg.phys_dims();
        Self {
            cfg,
            cluster: ClusterState::new(cfg.nx, cfg.ny),
            phys: cfg.has_spares().then(|| ClusterState::new(pnx, pny)),
            remap: LinkRemap::with_spares(cfg.nx, cfg.ny, cfg.spare_cols, cfg.spare_rows),
            rewires: 0,
            cache,
            sim_memo: HashMap::new(),
            load_memo: HashMap::new(),
            stats_base,
            link: LinkModel::tpu_v3(),
            estimator: EventRateEstimator::new(2.0 * cfg.horizon as f64),
            queue: VecDeque::new(),
            running: Vec::new(),
            done: Vec::new(),
            step: 0,
            now: 0.0,
            sample_every: (cfg.horizon / 64).max(1),
            transitions: 0,
            queue_waits: 0,
            backfills: 0,
            goodput_sum: 0.0,
            util_sum: 0.0,
            last_util: 0.0,
            last_good: 0.0,
            step_util_acc: 0.0,
            step_good_acc: 0.0,
            contention_epochs: 0,
            dilation_time: 0.0,
            dilation_weight: 0.0,
            max_dilation: 1.0,
            epoch_charge: Vec::new(),
            last_epoch_sig: None,
            last_epoch_dil: Vec::new(),
            last_epoch_max: 1.0,
            last_epoch_share: 1.0,
            link_occ: vec![0.0; cfg.nx * cfg.ny * 4],
            occ_touched: Vec::new(),
            segments: 0,
            samples: Vec::new(),
            events_log: Vec::new(),
            pidx: cfg.fast_placer.then(|| placer::PlacementIndex::new(cfg.nx, cfg.ny)),
            prof: FleetProfile::default(),
            pid: 0,
            reg: Registry::new(),
            last_epoch_contended: 0,
            has_serving: false,
            serving_intensity: cfg
                .workload
                .serving
                .as_ref()
                .map(|sv| sv.arrival.intensities(cfg.workload.seed, cfg.horizon))
                .unwrap_or_default(),
            serving_lat: Vec::new(),
            preemptions: 0,
        }
    }

    /// Current time in fleet steps, valid under both engines: the
    /// round-robin engine only advances `step` (leaving `now` at 0),
    /// the wall-clock engine keeps `now >= step`.
    fn now_steps(&self) -> f64 {
        self.now.max(self.step as f64)
    }

    fn log(&mut self, msg: String) {
        if let Some(trace) = &self.cfg.trace {
            trace.instant(self.pid, 0, &msg, self.now_steps() * STEP_US, &[]);
        }
        self.events_log.push((self.step, msg));
    }

    /// Trace thread id for a job track (tid 0 is the fleet-event
    /// track).
    fn job_tid(job_id: usize) -> u32 {
        job_id as u32 + 1
    }

    /// Record one recovery event: per-phase latency histograms and
    /// per-action counters in the registry, plus (when tracing) an
    /// async detect→resume span with phase children on the job's
    /// track. Async (`b`/`e`) spans are used because consecutive
    /// recoveries on one job can overlap in modelled time, which
    /// complete (`X`) spans cannot represent.
    fn record_recovery(&mut self, job_id: usize, action: &str, phases: RecoveryPhases) {
        self.reg.inc("recoveries", 1);
        self.reg.inc(&format!("recovery_{action}"), 1);
        self.reg.observe("recovery_detect_steps", phases.detect_steps);
        self.reg.observe("recovery_decide_steps", phases.decide_steps);
        self.reg.observe("recovery_heal_steps", phases.heal_steps);
        self.reg.observe("recovery_resume_steps", phases.resume_steps);
        self.reg.observe("recovery_total_steps", phases.total_steps());
        if let Some(trace) = &self.cfg.trace {
            let tid = Self::job_tid(job_id);
            let t0 = self.now_steps() * STEP_US;
            let id = trace.alloc_id();
            trace.begin(self.pid, tid, &format!("recover:{action}"), id, t0);
            let mut t = t0;
            for (phase, steps) in [
                ("detect", phases.detect_steps),
                ("decide", phases.decide_steps),
                ("heal", phases.heal_steps),
                ("resume", phases.resume_steps),
            ] {
                if steps > 0.0 {
                    let pid_span = trace.alloc_id();
                    trace.begin(self.pid, tid, phase, pid_span, t);
                    t += steps * STEP_US;
                    trace.end(self.pid, tid, phase, pid_span, t);
                }
            }
            trace.end(self.pid, tid, &format!("recover:{action}"), id, t);
        }
    }

    /// Emit the completed job's arrival→completion lifetime span on
    /// its trace track (one `X` span per job, so per-track nesting is
    /// trivially satisfied).
    fn trace_job_span(&self, job: &Job) {
        let Some(trace) = &self.cfg.trace else {
            return;
        };
        let done = job.completed_at.expect("traced job completed") as f64;
        let t0 = job.spec.arrival_step as f64 * STEP_US;
        let dur = (done - job.spec.arrival_step as f64).max(0.0) * STEP_US;
        trace.span(
            self.pid,
            Self::job_tid(job.spec.id),
            &format!(
                "job {} ({}x{} {})",
                job.spec.id,
                job.spec.w,
                job.spec.h,
                job.spec.policy.name()
            ),
            t0,
            dur,
            &[
                ("workers", job.workers as f64),
                ("migrations", job.migrations as f64),
                ("shrinks", job.shrinks as f64),
                ("ft_continues", job.ft_continues as f64),
                ("waited_steps", job.waited as f64),
            ],
        );
    }

    fn rect(&self, i: usize) -> Rect {
        self.running[i].rect.expect("running job has a rectangle")
    }

    fn local_holes(&self, i: usize) -> Vec<Rect> {
        let r = self.rect(i);
        self.running[i].holes.iter().map(|h| placer::to_local(&r, h)).collect()
    }

    /// The rectangle's slice of the global link remap, `None` when the
    /// slice is contiguous (no bypasses cross the rectangle — the
    /// plain unremapped path applies, sharing plan fingerprints and
    /// memo entries with unspared runs).
    fn submap_for(&self, r: &Rect) -> Option<LinkRemap> {
        if self.remap.is_identity() {
            return None;
        }
        let sub = self.remap.submap(r.x0, r.y0, r.w, r.h);
        (!sub.is_identity()).then_some(sub)
    }

    fn sim_key(w: usize, h: usize, holes: &[Rect], remap: Option<&LinkRemap>) -> SimKey {
        let mut key_holes = holes.to_vec();
        key_holes.sort_unstable();
        let spans = match remap {
            Some(m) => m.link_spans(&Mesh::new(w, h)),
            None => Vec::new(),
        };
        (w, h, key_holes, spans)
    }

    /// Ensure the simulation record for a hole-carrying `w x h`
    /// sub-mesh is memoized; `Ok(false)` = not schedulable (e.g. the
    /// holes break the pair-row planner or disconnect the sub-mesh).
    /// With a (non-trivial) remap slice the plan still compiles
    /// against the clean logical rectangle, but the DES prices every
    /// logical link at its physical bypass span.
    fn ensure_sim(&mut self, key: &SimKey, remap: Option<&LinkRemap>) -> Result<bool, FleetError> {
        if self.sim_memo.contains_key(key) {
            return Ok(true);
        }
        let topo = Topology::with_failures(key.0, key.1, key.2.clone());
        if !topo.is_connected() {
            return Ok(false);
        }
        if self.cfg.trace.is_some() {
            self.cache.trace_now(self.now_steps() * STEP_US);
        }
        let got = self.cache.get_remapped(Scheme::FaultTolerant, &topo, self.cfg.payload, remap);
        match got {
            Ok(plan) => {
                let report = match remap {
                    Some(m) => simulate_plan_remapped(&plan, &self.link, m)?,
                    None => simulate_plan(&plan, &self.link)?,
                };
                let step_s = self.cfg.compute_s + report.makespan_s;
                self.reg.inc("des_sims", 1);
                self.reg.inc("des_links_used", report.links.links_used() as u64);
                let busy_acc = self.reg.gauge("des_link_busy_s").unwrap_or(0.0);
                self.reg.set_gauge("des_link_busy_s", busy_acc + report.links.total_busy_s());
                self.reg.observe("des_makespan_ms", report.makespan_s * 1e3);
                let busy: Vec<(usize, f64)> = report.links.busy_slots().collect();
                self.sim_memo.insert(key.clone(), StepSim { step_s, busy });
                Ok(true)
            }
            Err(PlanError::Build(_)) => Ok(false),
            Err(e) => Err(e.into()),
        }
    }

    /// Predicted seconds per training step on a hole-carrying
    /// rectangle of the logical mesh: modelled compute + simulated FT
    /// allreduce through the shared plan cache, under the adopted
    /// remap's bypass spans. `None` = not schedulable.
    fn step_time(&mut self, rect: &Rect, holes: &[Rect]) -> Result<Option<f64>, FleetError> {
        let sub = self.submap_for(rect);
        self.step_time_under(sub.as_ref(), rect.w, rect.h, holes)
    }

    /// [`Self::step_time`] under an explicit remap slice (the heal
    /// arbitration compares candidate remaps that are not yet
    /// adopted).
    fn step_time_under(
        &mut self,
        remap: Option<&LinkRemap>,
        w: usize,
        h: usize,
        holes: &[Rect],
    ) -> Result<Option<f64>, FleetError> {
        let key = Self::sim_key(w, h, holes, remap);
        if !self.ensure_sim(&key, remap)? {
            return Ok(None);
        }
        Ok(self.sim_memo.get(&key).map(|s| s.step_s))
    }

    /// Current placement obstacles: live failed regions plus every
    /// running job's rectangle except `skip`.
    fn obstacles_excluding(&self, skip: usize) -> Vec<Rect> {
        let mut obs: Vec<Rect> = self.cluster.failed_regions().to_vec();
        for (i, j) in self.running.iter().enumerate() {
            if i == skip {
                continue;
            }
            obs.push(j.rect.expect("running job has a rectangle"));
        }
        obs
    }

    /// Place a `w x h` job against the current obstacles, excluding
    /// running job `skip` (`usize::MAX` excludes nobody). Fast path:
    /// query the placement index, briefly lifting `skip`'s rectangle
    /// out. Dense path: rebuild the obstacle list and scan. Both are
    /// bit-identical (`rust/tests/fleet_placement.rs`).
    fn place_excluding(&mut self, skip: usize, w: usize, h: usize) -> Option<Rect> {
        let t0 = Instant::now();
        let got = if self.pidx.is_some() {
            let skip_rect =
                self.running.get(skip).map(|j| j.rect.expect("running job has a rectangle"));
            let idx = self.pidx.as_mut().expect("fast path checked");
            if let Some(r) = skip_rect {
                idx.remove(&r);
            }
            let got = idx.place_oriented(w, h);
            if let Some(r) = skip_rect {
                idx.add(&r);
            }
            got
        } else {
            let obs = self.obstacles_excluding(skip);
            placer::place_oriented(self.cfg.nx, self.cfg.ny, &obs, w, h)
        };
        self.prof.placement_s += t0.elapsed().as_secs_f64();
        got
    }

    /// Effective throughput of a candidate over the expected horizon
    /// to the next event (the fleet-level adaptive comparison).
    fn eff(&self, workers: usize, step_s: f64, one_off_s: f64, rollback_steps: f64) -> f64 {
        let pred = CandidatePrediction {
            workers,
            allreduce_s: (step_s - self.cfg.compute_s).max(0.0),
            step_s,
            throughput: workers as f64 / step_s,
        };
        let cost = CandidateCost { one_off_s, rollback_steps };
        effective_throughput(&pred, self.estimator.expected_gap_steps(), &cost)
    }

    /// Job steps rolled back by a restart: progress past the last
    /// implicit checkpoint.
    fn rollback_of(&self, progress: f64) -> f64 {
        let every = self.cfg.checkpoint_every.max(1) as f64;
        progress - (progress / every).floor() * every
    }

    fn start_job(&mut self, job: &mut Job, rect: Rect) -> Result<(), FleetError> {
        let Some(s) = self.step_time(&rect, &[])? else {
            return Err(FleetError::Unschedulable(job.spec.id, rect.w, rect.h));
        };
        job.rect = Some(rect);
        if let Some(idx) = self.pidx.as_mut() {
            idx.add(&rect);
        }
        job.holes.clear();
        job.workers = rect.num_chips();
        job.rate = self.cfg.compute_s / s;
        job.dilation = 1.0;
        job.pause = if job.started { self.cfg.restart_steps } else { 0.0 };
        job.started = true;
        self.log(format!(
            "job {} placed: {}x{} at ({},{})",
            job.spec.id, rect.w, rect.h, rect.x0, rect.y0
        ));
        Ok(())
    }

    /// Priority admission for the serving tier: serving jobs anywhere
    /// in the queue place immediately when a rectangle is clear and,
    /// with [`FleetConfig::serving_preemption`], evict training
    /// placements when not. Runs before FIFO admission, so serving
    /// never queues behind training.
    fn admit_serving(&mut self) -> Result<(), FleetError> {
        if !self.has_serving {
            return Ok(());
        }
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].spec.class != JobClass::Serving {
                i += 1;
                continue;
            }
            let (w, h) = {
                let s = &self.queue[i].spec;
                (s.w, s.h)
            };
            if let Some(rect) = self.place_excluding(usize::MAX, w, h) {
                let mut job = self.queue.remove(i).expect("index checked");
                self.start_job(&mut job, rect)?;
                self.running.push(job);
                continue;
            }
            if !self.cfg.serving_preemption {
                i += 1;
                continue;
            }
            let mut job = self.queue.remove(i).expect("index checked");
            match self.preempt_for_serving(w, h) {
                Some(rect) => {
                    self.start_job(&mut job, rect)?;
                    self.running.push(job);
                    // Evicted training jobs were pushed to the queue
                    // front; rescan from the top so any serving job
                    // behind them is still reached.
                    i = 0;
                }
                None => {
                    self.queue.insert(i, job);
                    i += 1;
                }
            }
        }
        Ok(())
    }

    /// Find a rectangle for a `w x h` serving job by treating training
    /// placements as preemptible: plan against failed regions plus
    /// running *serving* rectangles only, then checkpoint-evict every
    /// training job overlapping the chosen target. Dense scan on
    /// purpose — the probe ignores most live obstacles, so the
    /// incremental index does not apply (and fast/dense runs stay
    /// bit-identical).
    fn preempt_for_serving(&mut self, w: usize, h: usize) -> Option<Rect> {
        let t0 = Instant::now();
        let mut obs: Vec<Rect> = self.cluster.failed_regions().to_vec();
        for j in &self.running {
            if j.spec.class == JobClass::Serving {
                obs.push(j.rect.expect("running job has a rectangle"));
            }
        }
        let got = placer::place_oriented(self.cfg.nx, self.cfg.ny, &obs, w, h);
        self.prof.placement_s += t0.elapsed().as_secs_f64();
        let target = got?;
        // Descending index order keeps lower indices valid while jobs
        // are removed; the push_front reversal restores ascending
        // order at the queue head.
        for i in (0..self.running.len()).rev() {
            if self.running[i].spec.class == JobClass::Training
                && self.rect(i).overlaps(&target)
            {
                self.preempt_training(i);
            }
        }
        Some(target)
    }

    /// Checkpoint-evict training job `i` for a serving placement: roll
    /// back to the last checkpoint, release the rectangle, and requeue
    /// it at the front — it re-places through the normal admission
    /// path, paying the same restart pause a migration would.
    fn preempt_training(&mut self, i: usize) {
        let mut j = self.running.remove(i);
        if let Some(idx) = self.pidx.as_mut() {
            let old = j.rect.expect("running job has a rectangle");
            let _removed = idx.remove(&old);
            debug_assert!(_removed, "preemption releases an indexed rectangle");
        }
        let rb = self.rollback_of(j.progress);
        self.goodput_sum -= j.workers as f64 * rb;
        let old_rate = j.rate;
        j.progress -= rb;
        j.rect = None;
        j.holes.clear();
        j.workers = 0;
        j.rate = 0.0;
        j.dilation = 1.0;
        j.pause = 0.0;
        self.preemptions += 1;
        self.reg.inc("preemptions", 1);
        let id = j.spec.id;
        self.log(format!("job {id} preempted for serving (rolled back {rb:.0} steps)"));
        self.record_recovery(
            id,
            "preempt",
            RecoveryPhases {
                heal_steps: self.cfg.restart_steps,
                resume_steps: if old_rate > 0.0 { rb / old_rate } else { 0.0 },
                ..RecoveryPhases::default()
            },
        );
        self.queue.push_front(j);
    }

    /// Admit queued jobs FIFO while the head fits; with
    /// [`FleetConfig::backfill`], admit later jobs around a blocked
    /// head (the head stays unplaceable throughout — obstacles only
    /// grow — so backfill never steals a feasible head placement).
    /// Serving jobs are admitted first ([`Self::admit_serving`]).
    fn try_admit(&mut self) -> Result<(), FleetError> {
        self.admit_serving()?;
        loop {
            let Some((w, h)) = self.queue.front().map(|j| (j.spec.w, j.spec.h)) else {
                return Ok(());
            };
            match self.place_excluding(usize::MAX, w, h) {
                Some(rect) => {
                    let mut job = self.queue.pop_front().expect("queue head exists");
                    self.start_job(&mut job, rect)?;
                    self.running.push(job);
                }
                None => break,
            }
        }
        if !self.cfg.backfill || self.queue.len() < 2 {
            return Ok(());
        }
        let head_id = self.queue.front().expect("head exists").spec.id;
        let mut i = 1;
        while i < self.queue.len() {
            let (w, h, id) = {
                let j = &self.queue[i];
                (j.spec.w, j.spec.h, j.spec.id)
            };
            match self.place_excluding(usize::MAX, w, h) {
                Some(rect) => {
                    let mut job = self.queue.remove(i).expect("index checked");
                    self.start_job(&mut job, rect)?;
                    self.running.push(job);
                    self.backfills += 1;
                    self.log(format!("job {id} backfilled around blocked head {head_id}"));
                }
                None => i += 1,
            }
        }
        Ok(())
    }

    /// The clear even sub-rectangle a shrink would restart on, cluster
    /// coords.
    fn shrink_target(&self, i: usize) -> Option<Rect> {
        let rect = self.rect(i);
        let local = self.local_holes(i);
        let (lx, ly, lw, lh) = placer::largest_clear_rect(rect.w, rect.h, &local);
        if lw * lh == 0 {
            return None;
        }
        let sub = placer::even_shrink(&Rect::new(lx, ly, lw, lh))?;
        Some(placer::to_cluster(&rect, &sub))
    }

    /// Restart job `i` on `target` (shrink within its own allocation,
    /// or a migration elsewhere), rolling back to the last checkpoint.
    fn restart_on(
        &mut self,
        i: usize,
        target: Rect,
        kind: RestartKind,
    ) -> Result<bool, FleetError> {
        let Some(s) = self.step_time(&target, &[])? else {
            return Ok(false);
        };
        let (progress, old_workers, class) = {
            let j = &self.running[i];
            (j.progress, j.workers, j.spec.class)
        };
        let rb = self.rollback_of(progress);
        // Rolled-back work must be redone: debit it from the net
        // goodput at the pre-transition worker count. Goodput is a
        // training-progress figure, so serving jobs neither credit nor
        // debit it.
        if class == JobClass::Training {
            self.goodput_sum -= old_workers as f64 * rb;
        }
        let pause = match kind {
            RestartKind::Shrink => self.cfg.restart_steps,
            RestartKind::Migrate => self.cfg.restart_steps + self.cfg.migrate_steps,
        };
        if self.pidx.is_some() {
            let old = self.running[i].rect.expect("running job has a rectangle");
            let idx = self.pidx.as_mut().expect("fast path checked");
            let _removed = idx.remove(&old);
            debug_assert!(_removed, "restart_on lifts an indexed rectangle");
            idx.add(&target);
        }
        let j = &mut self.running[i];
        j.progress -= rb;
        j.rect = Some(target);
        j.holes.clear();
        j.workers = target.num_chips();
        j.rate = self.cfg.compute_s / s;
        j.dilation = 1.0;
        j.pause += pause;
        let id = j.spec.id;
        let verb = match kind {
            RestartKind::Shrink => {
                j.shrinks += 1;
                "shrinks to"
            }
            RestartKind::Migrate => {
                j.migrations += 1;
                "migrates to"
            }
        };
        let rate = self.running[i].rate;
        self.log(format!(
            "job {id} {verb} {}x{} at ({},{}) (rolled back {rb:.0} steps)",
            target.w, target.h, target.x0, target.y0
        ));
        let action = match kind {
            RestartKind::Shrink => "shrink",
            RestartKind::Migrate => "migrate",
        };
        self.record_recovery(
            id,
            action,
            RecoveryPhases {
                heal_steps: pause,
                // Rolled-back job steps redone at the post-recovery
                // rate, in fleet steps.
                resume_steps: if rate > 0.0 { rb / rate } else { 0.0 },
                ..RecoveryPhases::default()
            },
        );
        Ok(true)
    }

    /// Try one recovery action on job `i`; `Ok(false)` = infeasible.
    fn try_action(&mut self, i: usize, action: Action) -> Result<bool, FleetError> {
        match action {
            Action::Ft => {
                let rect = self.rect(i);
                let local = self.local_holes(i);
                let Some(s) = self.step_time(&rect, &local)? else {
                    return Ok(false);
                };
                let holes_chips: usize =
                    self.running[i].holes.iter().map(|h| h.num_chips()).sum();
                let workers = rect.num_chips().saturating_sub(holes_chips);
                if workers == 0 {
                    return Ok(false);
                }
                let j = &mut self.running[i];
                j.workers = workers;
                j.rate = self.cfg.compute_s / s;
                j.pause += self.cfg.rebuild_steps;
                j.ft_continues += 1;
                let id = j.spec.id;
                self.log(format!("job {id} continues fault-tolerant ({workers} workers)"));
                self.record_recovery(
                    id,
                    "continue-ft",
                    RecoveryPhases {
                        heal_steps: self.cfg.rebuild_steps,
                        ..RecoveryPhases::default()
                    },
                );
                Ok(true)
            }
            Action::Shrink => match self.shrink_target(i) {
                Some(target) => self.restart_on(i, target, RestartKind::Shrink),
                None => Ok(false),
            },
            Action::Migrate => {
                let (w, h) = {
                    let s = &self.running[i].spec;
                    (s.w, s.h)
                };
                match self.place_excluding(i, w, h) {
                    Some(target) => self.restart_on(i, target, RestartKind::Migrate),
                    None => Ok(false),
                }
            }
            Action::Wait => {
                let mut j = self.running.remove(i);
                if let Some(idx) = self.pidx.as_mut() {
                    let old = j.rect.expect("running job has a rectangle");
                    let _removed = idx.remove(&old);
                    debug_assert!(_removed, "wait releases an indexed rectangle");
                }
                let rb = self.rollback_of(j.progress);
                if j.spec.class == JobClass::Training {
                    self.goodput_sum -= j.workers as f64 * rb;
                }
                j.progress -= rb;
                j.rect = None;
                j.holes.clear();
                j.workers = 0;
                j.rate = 0.0;
                j.dilation = 1.0;
                j.pause = 0.0;
                self.queue_waits += 1;
                self.reg.inc("recovery_queue_wait", 1);
                self.log(format!("job {} releases its rectangle and queues", j.spec.id));
                self.queue.push_back(j);
                Ok(true)
            }
        }
    }

    /// Try actions in order; the first feasible one wins. `Wait` is
    /// always feasible, so this cannot fall through.
    fn recover_with(&mut self, i: usize, order: &[Action]) -> Result<(), FleetError> {
        for &a in order {
            if self.try_action(i, a)? {
                return Ok(());
            }
        }
        self.try_action(i, Action::Wait)?;
        Ok(())
    }

    /// Adaptive arbitration for job `i`: predict every feasible
    /// candidate's effective throughput over the expected
    /// time-to-next-event (one-off transition costs + checkpoint
    /// rollback folded in) and apply the best.
    fn adaptive_recover(&mut self, i: usize) -> Result<(), FleetError> {
        let rect = self.rect(i);
        let local = self.local_holes(i);
        let rb = self.rollback_of(self.running[i].progress);
        let mut cands: Vec<(f64, Action)> = Vec::new();
        if let Some(s) = self.step_time(&rect, &local)? {
            let holes_chips: usize = self.running[i].holes.iter().map(|h| h.num_chips()).sum();
            let workers = rect.num_chips().saturating_sub(holes_chips);
            if workers > 0 {
                cands.push((self.eff(workers, s, self.cfg.rebuild_steps * s, 0.0), Action::Ft));
            }
        }
        {
            let (w, h) = {
                let s = &self.running[i].spec;
                (s.w, s.h)
            };
            if let Some(t) = self.place_excluding(i, w, h) {
                if let Some(s) = self.step_time(&t, &[])? {
                    let one_off = (self.cfg.restart_steps + self.cfg.migrate_steps) * s;
                    cands.push((self.eff(t.num_chips(), s, one_off, rb), Action::Migrate));
                }
            }
        }
        if let Some(t) = self.shrink_target(i) {
            if let Some(s) = self.step_time(&t, &[])? {
                let one_off = self.cfg.restart_steps * s;
                cands.push((self.eff(t.num_chips(), s, one_off, rb), Action::Shrink));
            }
        }
        // Strictly-greater keeps the earlier candidate on ties, so the
        // preference order FT > migrate > shrink breaks exact ties.
        let mut best: Option<(f64, Action)> = None;
        for (e, a) in cands {
            let better = match best {
                None => true,
                Some((be, _)) => e > be,
            };
            if better {
                best = Some((e, a));
            }
        }
        match best {
            Some((e, a)) => {
                let id = self.running[i].spec.id;
                self.reg.inc("adaptive_decisions", 1);
                self.log(format!(
                    "adaptive: job {id} -> {} (predicted effective throughput {e:.1})",
                    a.name()
                ));
                if !self.try_action(i, a)? {
                    self.try_action(i, Action::Wait)?;
                }
            }
            None => {
                self.try_action(i, Action::Wait)?;
            }
        }
        Ok(())
    }

    /// Route a failure/repair consequence to job `i`'s policy.
    fn recover(&mut self, i: usize) -> Result<(), FleetError> {
        match self.running[i].spec.policy {
            JobPolicy::Continue => {
                self.recover_with(i, &[Action::Ft, Action::Shrink, Action::Migrate])
            }
            JobPolicy::Shrink => self.recover_with(i, &[Action::Shrink]),
            JobPolicy::Migrate => self.recover_with(i, &[Action::Migrate, Action::Shrink]),
            JobPolicy::Wait => self.recover_with(i, &[]),
            // By recovery time the healing planner has already run on
            // the physical ledger: any hole still visible means spares
            // were exhausted (or never provisioned), so the job
            // degrades gracefully to the continue-FT ladder.
            JobPolicy::Reconfigure => {
                self.recover_with(i, &[Action::Ft, Action::Shrink, Action::Migrate])
            }
            JobPolicy::Adaptive => self.adaptive_recover(i),
        }
    }

    fn on_fail(&mut self, region: FailedRegion) -> Result<(), FleetError> {
        self.estimator.observe(self.step);
        self.transitions += 1;
        self.apply_fail(region)
    }

    /// Surface a **logical** failure: register holes with the affected
    /// jobs and run their recovery policies. (The observation/counter
    /// bookkeeping lives in the per-event wrappers so the spared
    /// remap-diff path can replay several logical changes per physical
    /// event without inflating the estimator.)
    fn apply_fail(&mut self, region: FailedRegion) -> Result<(), FleetError> {
        self.cluster.fail(region)?;
        if let Some(idx) = self.pidx.as_mut() {
            idx.add(&region);
        }
        self.log(format!("fail {region:?}"));
        // Descending order: a queue-wait decision removes its own
        // index and leaves lower ones valid.
        let affected: Vec<usize> = (0..self.running.len())
            .rev()
            .filter(|&i| self.rect(i).overlaps(&region))
            .collect();
        for i in affected {
            let cut = placer::intersect(&self.rect(i), &region).expect("overlap checked");
            self.running[i].holes.push(cut);
            self.recover(i)?;
        }
        Ok(())
    }

    fn on_repair(&mut self, region: FailedRegion) -> Result<(), FleetError> {
        self.estimator.observe(self.step);
        self.transitions += 1;
        self.apply_repair(region)?;
        self.grow_back()?;
        self.try_admit()?;
        self.defragment()?;
        Ok(())
    }

    /// Clear a **logical** failure and rejoin the jobs holding a piece
    /// of it. Callers follow up with grow-back/admission/defrag once
    /// per batch.
    fn apply_repair(&mut self, region: FailedRegion) -> Result<(), FleetError> {
        self.cluster.repair(region)?;
        if let Some(idx) = self.pidx.as_mut() {
            let _removed = idx.remove(&region);
            debug_assert!(_removed, "repair clears an indexed failed region");
        }
        self.log(format!("repair {region:?}"));
        // Jobs holding a piece of the repaired region rejoin in place.
        for i in (0..self.running.len()).rev() {
            let rect = self.rect(i);
            if !rect.overlaps(&region) {
                continue;
            }
            self.running[i].holes.retain(|h| !h.overlaps(&region));
            let local = self.local_holes(i);
            if let Some(s) = self.step_time(&rect, &local)? {
                let holes_chips: usize =
                    self.running[i].holes.iter().map(|h| h.num_chips()).sum();
                let j = &mut self.running[i];
                j.workers = rect.num_chips().saturating_sub(holes_chips);
                j.rate = self.cfg.compute_s / s;
                j.pause += self.cfg.rebuild_steps;
                let (id, workers) = (j.spec.id, j.workers);
                self.log(format!("job {id} rejoins repaired chips ({workers} workers)"));
                self.record_recovery(
                    id,
                    "rejoin",
                    RecoveryPhases {
                        heal_steps: self.cfg.rebuild_steps,
                        ..RecoveryPhases::default()
                    },
                );
            } else {
                // Other holes still make the rectangle unschedulable.
                self.recover(i)?;
            }
        }
        Ok(())
    }

    /// A failure on the **physical** mesh (spares provisioned): ledger
    /// it, re-run the healing planner, and surface whatever logical
    /// holes the (possibly re-adopted) remap leaves visible.
    fn on_phys_fail(&mut self, region: FailedRegion) -> Result<(), FleetError> {
        self.estimator.observe(self.step);
        self.transitions += 1;
        self.phys.as_mut().expect("spared path").fail(region)?;
        self.log(format!("fail {region:?} (physical)"));
        self.maybe_reconfigure(false)
    }

    /// A physical repair: ledger it and re-run the healing planner —
    /// repaired rows/columns let the healer hand spares back.
    fn on_phys_repair(&mut self, region: FailedRegion) -> Result<(), FleetError> {
        self.estimator.observe(self.step);
        self.transitions += 1;
        self.phys.as_mut().expect("spared path").repair(region)?;
        self.log(format!("repair {region:?} (physical)"));
        self.maybe_reconfigure(false)
    }

    /// Re-run the healing planner on the physical ledger and adopt its
    /// remap if the affected jobs vote for it (`force` skips the vote —
    /// the scenario `reconfig` event). Either way, the logical cluster
    /// is re-synced to the visible holes of the remap in force.
    fn maybe_reconfigure(&mut self, force: bool) -> Result<(), FleetError> {
        let phys = self.phys.as_ref().expect("spared path");
        let (pnx, pny) = (phys.nx, phys.ny);
        let outcome = heal(pnx, pny, self.cfg.nx, self.cfg.ny, phys.failed_regions());
        if outcome.remap != self.remap && (force || self.heal_vote(&outcome.remap)?) {
            self.remap = outcome.remap;
            self.rewires += 1;
            // Every running job pauses while the bypass switches flip;
            // chips newly mapped into a rectangle copy parameters from
            // a live data-parallel peer, so nobody rolls back.
            for j in &mut self.running {
                j.pause += self.cfg.rewire_steps;
            }
            let (n, bypassed, unhealed) =
                (self.rewires, self.remap.bypassed_chips(), outcome.unhealed.len());
            self.log(format!(
                "reconfigured: heal #{n} bypasses {bypassed} chips ({unhealed} regions unhealed)"
            ));
            // The rewire pauses every running job at once, so it is
            // recorded as one fleet-level recovery on the event track
            // (tid 0) rather than per job.
            self.reg.inc("recoveries", 1);
            self.reg.inc("recovery_reconfigure", 1);
            self.reg.observe("recovery_detect_steps", 0.0);
            self.reg.observe("recovery_decide_steps", 0.0);
            self.reg.observe("recovery_heal_steps", self.cfg.rewire_steps);
            self.reg.observe("recovery_resume_steps", 0.0);
            self.reg.observe("recovery_total_steps", self.cfg.rewire_steps);
            if let Some(trace) = &self.cfg.trace {
                let t0 = self.now_steps() * STEP_US;
                let id = trace.alloc_id();
                trace.begin(self.pid, 0, "recover:reconfigure", id, t0);
                let t1 = t0 + self.cfg.rewire_steps * STEP_US;
                trace.end(self.pid, 0, "recover:reconfigure", id, t1);
            }
        }
        self.sync_visible()
    }

    /// Do the jobs whose holes a candidate remap would change want it?
    /// A `Reconfigure` job always votes yes; an `Adaptive` job votes
    /// yes when the healed candidate's predicted effective throughput
    /// (one-off rewire + rebuild, no rollback) beats fault-tolerant
    /// continue under the current remap. Unaffected jobs abstain.
    fn heal_vote(&mut self, candidate: &LinkRemap) -> Result<bool, FleetError> {
        let phys_failed = self.phys.as_ref().expect("spared path").failed_regions().to_vec();
        let cur_vis = self.remap.visible_holes(&phys_failed);
        let new_vis = candidate.visible_holes(&phys_failed);
        let local_of = |rect: &Rect, vis: &[FailedRegion]| -> Vec<Rect> {
            let mut cuts: Vec<Rect> = vis
                .iter()
                .filter_map(|h| placer::intersect(rect, h))
                .map(|c| placer::to_local(rect, &c))
                .collect();
            cuts.sort_unstable();
            cuts
        };
        let mut adaptive: Vec<(Rect, Vec<Rect>, Vec<Rect>)> = Vec::new();
        for i in 0..self.running.len() {
            let rect = self.rect(i);
            let cur_local = local_of(&rect, &cur_vis);
            let new_local = local_of(&rect, &new_vis);
            if cur_local == new_local {
                continue;
            }
            match self.running[i].spec.policy {
                JobPolicy::Reconfigure => return Ok(true),
                JobPolicy::Adaptive => adaptive.push((rect, cur_local, new_local)),
                _ => {}
            }
        }
        let hole_chips = |hs: &[Rect]| hs.iter().map(|h| h.num_chips()).sum::<usize>();
        for (rect, cur_local, new_local) in adaptive {
            let cur_sub = self.submap_for(&rect);
            let ft_s = self.step_time_under(cur_sub.as_ref(), rect.w, rect.h, &cur_local)?;
            let new_sub = {
                let s = candidate.submap(rect.x0, rect.y0, rect.w, rect.h);
                (!s.is_identity()).then_some(s)
            };
            let heal_s = self.step_time_under(new_sub.as_ref(), rect.w, rect.h, &new_local)?;
            let ft_eff = ft_s.and_then(|s| {
                let w = rect.num_chips().saturating_sub(hole_chips(&cur_local));
                (w > 0).then(|| self.eff(w, s, self.cfg.rebuild_steps * s, 0.0))
            });
            let heal_eff = heal_s.and_then(|s| {
                let w = rect.num_chips().saturating_sub(hole_chips(&new_local));
                let one_off = (self.cfg.rewire_steps + self.cfg.rebuild_steps) * s;
                (w > 0).then(|| self.eff(w, s, one_off, 0.0))
            });
            match (heal_eff, ft_eff) {
                (Some(h), Some(f)) if h > f => return Ok(true),
                (Some(_), None) => return Ok(true),
                _ => {}
            }
        }
        Ok(false)
    }

    /// Diff the logical cluster against the visible holes of the remap
    /// in force and replay the difference through the normal logical
    /// fail/repair paths (jobs rejoin healed holes, keep or recover
    /// remaining ones). Repairs run before fails so the transient
    /// ledger never holds overlapping regions.
    fn sync_visible(&mut self) -> Result<(), FleetError> {
        let phys = self.phys.as_ref().expect("spared path");
        let mut want = self.remap.visible_holes(phys.failed_regions());
        want.sort_unstable();
        let mut have = self.cluster.failed_regions().to_vec();
        have.sort_unstable();
        let repairs: Vec<FailedRegion> =
            have.iter().filter(|r| !want.contains(r)).copied().collect();
        let fails: Vec<FailedRegion> =
            want.iter().filter(|r| !have.contains(r)).copied().collect();
        let repaired_any = !repairs.is_empty();
        for r in repairs {
            self.apply_repair(r)?;
        }
        for r in fails {
            self.apply_fail(r)?;
        }
        if repaired_any {
            self.grow_back()?;
            self.try_admit()?;
            self.defragment()?;
        }
        Ok(())
    }

    /// After a repair, offer shrunk jobs their full-size rectangle
    /// back (adaptive jobs take it only when it wins the effective-
    /// throughput comparison net of migration costs).
    fn grow_back(&mut self) -> Result<(), FleetError> {
        for i in 0..self.running.len() {
            let (cur, sw, sh, policy, workers) = {
                let j = &self.running[i];
                (j.rect.expect("running"), j.spec.w, j.spec.h, j.spec.policy, j.workers)
            };
            if cur.num_chips() >= sw * sh {
                continue;
            }
            let Some(target) = self.place_excluding(i, sw, sh) else {
                continue;
            };
            let grow = match policy {
                JobPolicy::Adaptive => {
                    let rb = self.rollback_of(self.running[i].progress);
                    let local = self.local_holes(i);
                    let cur_s = self.step_time(&cur, &local)?;
                    let tgt_s = self.step_time(&target, &[])?;
                    match (cur_s, tgt_s) {
                        (Some(cs), Some(ts)) => {
                            let one_off = (self.cfg.restart_steps + self.cfg.migrate_steps) * ts;
                            self.eff(target.num_chips(), ts, one_off, rb)
                                > self.eff(workers, cs, 0.0, 0.0)
                        }
                        (None, Some(_)) => true,
                        _ => false,
                    }
                }
                _ => true,
            };
            if grow {
                self.restart_on(i, target, RestartKind::Migrate)?;
            }
        }
        Ok(())
    }

    /// Defragmenting re-placement: when the queue head still does not
    /// fit after a repair, repack every running job bottom-left-first
    /// (largest first) and admit the head if the compacted layout has
    /// room. Moved jobs pay the migration cost.
    fn defragment(&mut self) -> Result<(), FleetError> {
        let Some((hw, hh)) = self.queue.front().map(|j| (j.spec.w, j.spec.h)) else {
            return Ok(());
        };
        let t0 = Instant::now();
        // Serving rectangles are pinned: repacking them would restart
        // a latency-SLO tier to tidy a batch one. Only training jobs
        // move (with no serving jobs this filter keeps everything —
        // the pre-serving behaviour, bit for bit).
        let mut order: Vec<usize> = (0..self.running.len())
            .filter(|&i| self.running[i].spec.class == JobClass::Training)
            .collect();
        order.sort_by_key(|&i| std::cmp::Reverse(self.rect(i).num_chips()));
        // Trial layout: failed regions plus pinned serving rectangles
        // plus progressively committed trial rectangles. The fast path
        // plans on a scratch index (the live one still describes the
        // current layout until the commit below goes through
        // restart_on/start_job).
        let mut obs: Vec<Rect> = self.cluster.failed_regions().to_vec();
        for j in &self.running {
            if j.spec.class == JobClass::Serving {
                obs.push(j.rect.expect("running job has a rectangle"));
            }
        }
        let mut scratch = self.cfg.fast_placer.then(|| {
            let mut idx = placer::PlacementIndex::new(self.cfg.nx, self.cfg.ny);
            for r in &obs {
                idx.add(r);
            }
            idx
        });
        let mut placed: Vec<(usize, Rect)> = Vec::new();
        for &i in &order {
            let r = self.rect(i);
            let got = match &scratch {
                Some(idx) => idx.place_oriented(r.w, r.h),
                None => placer::place_oriented(self.cfg.nx, self.cfg.ny, &obs, r.w, r.h),
            };
            let Some(nr) = got else {
                self.prof.placement_s += t0.elapsed().as_secs_f64();
                return Ok(()); // compaction itself fails; keep layout
            };
            if let Some(idx) = scratch.as_mut() {
                idx.add(&nr);
            }
            obs.push(nr);
            placed.push((i, nr));
        }
        let head_got = match &scratch {
            Some(idx) => idx.place_oriented(hw, hh),
            None => placer::place_oriented(self.cfg.nx, self.cfg.ny, &obs, hw, hh),
        };
        self.prof.placement_s += t0.elapsed().as_secs_f64();
        let Some(head_rect) = head_got else {
            return Ok(()); // compaction would not admit the head
        };
        // Commit: move every job whose rectangle changed, then admit
        // the head. FT jobs being moved land on clean rectangles, so
        // their holes clear.
        for (i, nr) in placed {
            if self.rect(i) == nr {
                continue;
            }
            self.restart_on(i, nr, RestartKind::Migrate)?;
        }
        let mut job = self.queue.pop_front().expect("head exists");
        self.start_job(&mut job, head_rect)?;
        self.running.push(job);
        let queued = self.queue.len();
        self.log(format!("defragmented: head admitted, {queued} still queued"));
        Ok(())
    }

    fn handle_event(&mut self, ev: TimedEvent) -> Result<(), FleetError> {
        let t0 = Instant::now();
        let res = match ev.event {
            ClusterEvent::Fail(r) => {
                if self.phys.is_some() {
                    self.on_phys_fail(r)
                } else {
                    self.on_fail(r)
                }
            }
            ClusterEvent::Repair(r) => {
                if self.phys.is_some() {
                    self.on_phys_repair(r)
                } else {
                    self.on_repair(r)
                }
            }
            ClusterEvent::Reconfig => {
                // Operator-forced heal: adopt the planner's remap
                // without polling the affected jobs. Meaningless
                // without spares.
                if self.phys.is_some() {
                    self.maybe_reconfigure(true)
                } else {
                    Ok(())
                }
            }
            ClusterEvent::CheckpointTick | ClusterEvent::Stop => {
                // Checkpoints are an implicit cadence here; operator
                // stop is a single-job concept the fleet ignores.
                Ok(())
            }
        };
        self.prof.drain_s += t0.elapsed().as_secs_f64();
        res
    }

    /// Recompute the link epoch: charge every running job's compiled
    /// plan against per-edge occupancy and split contended edges
    /// max-min fairly. No-op unless the wall-clock engine runs with
    /// contention enabled.
    fn refresh_contention(&mut self) -> Result<(), FleetError> {
        let t0 = Instant::now();
        let res = self.refresh_contention_inner();
        self.prof.contention_s += t0.elapsed().as_secs_f64();
        res
    }

    fn refresh_contention_inner(&mut self) -> Result<(), FleetError> {
        let Some(model) = self.cfg.contention else {
            return Ok(());
        };
        if self.cfg.clock != ClockMode::WallClock {
            return Ok(());
        }
        if self.running.is_empty() {
            self.epoch_charge.clear();
            self.last_epoch_sig = None;
            return Ok(());
        }
        // Pass 1 (mutable): memoize every running job's simulation and
        // collect the epoch's placement signature.
        let mut keys: EpochSig = Vec::with_capacity(self.running.len());
        for i in 0..self.running.len() {
            let rect = self.rect(i);
            let local = self.local_holes(i);
            let sub = self.submap_for(&rect);
            let key = Self::sim_key(rect.w, rect.h, &local, sub.as_ref());
            let ok = self.ensure_sim(&key, sub.as_ref())?;
            keys.push((rect, key, ok, self.running[i].pause > 0.0));
        }
        // Unchanged placement signature ⇒ unchanged loads, and the
        // fair share is a pure function of the loads: replay the
        // stored epoch outputs instead of re-splitting. (The dense
        // reference path recomputes every epoch.)
        if self.cfg.sparse_occupancy && self.last_epoch_sig.as_ref() == Some(&keys) {
            for (j, &d) in self.running.iter_mut().zip(&self.last_epoch_dil) {
                j.dilation = d;
            }
            self.contention_epochs += 1;
            self.reg.inc("contention_epochs", 1);
            self.reg.inc("contended_edges", self.last_epoch_contended);
            if self.last_epoch_max > 1.0 + 1e-9 {
                let n = self.contention_epochs;
                let (epoch_max, epoch_share) = (self.last_epoch_max, self.last_epoch_share);
                self.log(format!(
                    "contention epoch {n}: max dilation {epoch_max:.3} \
                     (implied allreduce share {epoch_share:.3})"
                ));
            }
            return Ok(());
        }
        // Pass 2 (shared borrows only): build loads straight from the
        // memos — no per-epoch clones of the busy vectors, and on the
        // sparse path no re-translation of a placement already seen. A
        // paused job (mid restart/rebuild) streams no allreduce
        // traffic, so it charges nothing and sees no dilation;
        // `advance_to` cuts a fresh epoch the moment its pause expires.
        let empty = || contention::JobLoad { cap: 0.0, edges: Vec::new() };
        let mut loads = Vec::with_capacity(keys.len());
        for (rect, key, ok, paused) in &keys {
            if !*ok || *paused {
                // Paused, or (defensively) unschedulable/not memoized.
                loads.push(empty());
                continue;
            }
            if self.cfg.sparse_occupancy {
                if let Some(l) = self.load_memo.get(&(key.clone(), rect.x0, rect.y0)) {
                    loads.push(l.clone());
                    continue;
                }
            }
            let load = match self.sim_memo.get(key) {
                Some(sim) => contention::job_load(
                    self.cfg.nx,
                    self.cfg.ny,
                    rect,
                    &sim.busy,
                    sim.step_s,
                    self.cfg.compute_s,
                    &model,
                ),
                None => empty(),
            };
            if self.cfg.sparse_occupancy {
                self.load_memo.insert((key.clone(), rect.x0, rect.y0), load.clone());
            }
            loads.push(load);
        }
        let report = contention::fair_shares(model.capacity, &loads);
        let compute_s = self.cfg.compute_s;
        let mut max_d = self.max_dilation;
        let mut epoch_max = 1.0f64;
        let mut epoch_share = 1.0f64;
        let mut dils = Vec::with_capacity(loads.len());
        for ((j, load), &x) in self.running.iter_mut().zip(&loads).zip(&report.rates) {
            let q = load.cap;
            // The fair share grants a whole-step rate x <= q, so the
            // step dilates by exactly q / x (an uncontended job keeps
            // x == q bit-for-bit and stays at 1.0).
            let d = if q > 0.0 && x > 0.0 { (q / x).max(1.0) } else { 1.0 };
            j.dilation = d;
            dils.push(d);
            if d > epoch_max {
                // Physically the stretch lives in the bandwidth-bound
                // allreduce term; record the implied share of the most
                // contended job for the epoch diagnostic.
                epoch_max = d;
                let step_s = compute_s / q;
                let ar_s = (step_s - compute_s).max(0.0);
                epoch_share = steptime::contention_share(compute_s, ar_s, d);
            }
            max_d = max_d.max(d);
        }
        self.max_dilation = max_d;
        // Charged occupancy at the granted rates, for the hotspot
        // integral (all charged edges, not only contended ones) —
        // merged with one stable sort over the touched edges, which is
        // bit-identical to in-order map accumulation.
        let mut emitted: Vec<(usize, f64)> = Vec::new();
        for (i, load) in loads.iter().enumerate() {
            for &(slot, c) in &load.edges {
                emitted.push((slot, report.rates[i] * c));
            }
        }
        self.epoch_charge = contention::accumulate_sorted(emitted);
        self.last_epoch_sig = Some(keys);
        self.last_epoch_dil = dils;
        self.last_epoch_max = epoch_max;
        self.last_epoch_share = epoch_share;
        self.last_epoch_contended = report.contended_edges() as u64;
        self.contention_epochs += 1;
        self.reg.inc("contention_epochs", 1);
        self.reg.inc("contended_edges", self.last_epoch_contended);
        let peak = report.peak_occupancy();
        if peak > self.reg.gauge("peak_edge_occupancy").unwrap_or(0.0) {
            self.reg.set_gauge("peak_edge_occupancy", peak);
        }
        if epoch_max > 1.0 + 1e-9 {
            let n = self.contention_epochs;
            self.log(format!(
                "contention epoch {n}: max dilation {epoch_max:.3} \
                 (implied allreduce share {epoch_share:.3})"
            ));
        }
        Ok(())
    }

    /// One round-robin fleet step of training progress; returns
    /// whether any job completed (freed space → admission
    /// opportunity).
    fn advance(&mut self) -> bool {
        let t0 = Instant::now();
        self.segments += 1;
        let live = self.cluster.live_chips() as f64;
        let lam = intensity_at(&self.serving_intensity, self.step);
        let mut parcels: Vec<(f64, f64)> = Vec::new();
        let mut util = 0.0f64;
        let mut good = 0.0f64;
        let mut finished: Vec<usize> = Vec::new();
        for (i, j) in self.running.iter_mut().enumerate() {
            util += j.workers as f64;
            let pause_before = j.pause;
            let frac = if j.pause >= 1.0 {
                j.pause -= 1.0;
                0.0
            } else {
                let f = 1.0 - j.pause;
                j.pause = 0.0;
                f
            };
            if frac > 0.0 {
                let gained = j.rate * frac;
                j.progress += gained;
                if j.spec.class == JobClass::Training {
                    good += j.workers as f64 * gained;
                }
                if j.progress + 1e-9 >= j.spec.duration_steps as f64 {
                    finished.push(i);
                }
            }
            if j.spec.class == JobClass::Serving {
                serve_segment(j, self.cfg.compute_s, lam, 1.0, frac, pause_before, &mut parcels);
            }
        }
        for j in self.queue.iter_mut() {
            j.waited += 1;
            if j.spec.class == JobClass::Serving {
                queued_segment(j, lam, 1.0, &mut parcels);
            }
        }
        for &(_, lat) in &parcels {
            self.reg.observe("serving_latency_ms", lat);
        }
        self.serving_lat.extend(parcels);
        self.last_util = if live > 0.0 { util / live } else { 0.0 };
        self.last_good = good;
        self.util_sum += self.last_util;
        self.goodput_sum += good;
        let any = !finished.is_empty();
        for i in finished.into_iter().rev() {
            let mut job = self.running.remove(i);
            if let Some(idx) = self.pidx.as_mut() {
                let old = job.rect.expect("running job has a rectangle");
                let _removed = idx.remove(&old);
                debug_assert!(_removed, "completion releases an indexed rectangle");
            }
            job.completed_at = Some(self.step + 1);
            let (id, migrations) = (job.spec.id, job.migrations);
            self.log(format!("job {id} completes ({migrations} migrations)"));
            self.trace_job_span(&job);
            self.done.push(job);
        }
        self.prof.executor_s += t0.elapsed().as_secs_f64();
        any
    }

    /// Integrate `dt` fleet steps of wall-clock training. The per-job
    /// op sequence mirrors [`advance`](Self::advance) exactly at
    /// `dt == 1.0` with dilation 1.0 — the differential-equivalence
    /// contract with the round-robin engine. Returns indices of jobs
    /// whose work finished (ascending).
    fn advance_segment(&mut self, dt: f64) -> Vec<usize> {
        let t0 = Instant::now();
        self.segments += 1;
        let live = self.cluster.live_chips() as f64;
        // `now` is the segment start here (the caller moves it to the
        // segment end afterwards), so truncation lands on the same
        // integer step the round-robin engine would read.
        let lam = intensity_at(&self.serving_intensity, self.now as u64);
        let mut parcels: Vec<(f64, f64)> = Vec::new();
        let mut util = 0.0f64;
        let mut good = 0.0f64;
        let mut dil_time = 0.0f64;
        let mut dil_weight = 0.0f64;
        let mut finished: Vec<usize> = Vec::new();
        for (i, j) in self.running.iter_mut().enumerate() {
            util += j.workers as f64;
            dil_time += j.dilation * dt;
            dil_weight += dt;
            let pause_before = j.pause;
            let frac = if j.pause >= dt {
                j.pause -= dt;
                0.0
            } else {
                let f = dt - j.pause;
                j.pause = 0.0;
                f
            };
            if frac > 0.0 {
                let gained = (j.rate / j.dilation) * frac;
                j.progress += gained;
                if j.spec.class == JobClass::Training {
                    good += j.workers as f64 * gained;
                }
                if j.progress + 1e-9 >= j.spec.duration_steps as f64 {
                    finished.push(i);
                }
            }
            if j.spec.class == JobClass::Serving {
                serve_segment(j, self.cfg.compute_s, lam, dt, frac, pause_before, &mut parcels);
            }
        }
        for j in self.queue.iter_mut() {
            if j.spec.class == JobClass::Serving {
                queued_segment(j, lam, dt, &mut parcels);
            }
        }
        for &(_, lat) in &parcels {
            self.reg.observe("serving_latency_ms", lat);
        }
        self.serving_lat.extend(parcels);
        let u = if live > 0.0 { util / live } else { 0.0 };
        self.step_util_acc += u * dt;
        self.step_good_acc += good;
        self.dilation_time += dil_time;
        self.dilation_weight += dil_weight;
        let link_occ = &mut self.link_occ;
        let occ_touched = &mut self.occ_touched;
        for &(slot, occ) in &self.epoch_charge {
            if link_occ[slot] == 0.0 {
                occ_touched.push(slot as u32);
            }
            link_occ[slot] += occ * dt;
        }
        self.prof.executor_s += t0.elapsed().as_secs_f64();
        finished
    }

    /// Advance the wall clock to `target` (fleet-step units).
    /// Segments split at integer fleet-step boundaries — the metric
    /// grid utilization/goodput/queue-wait/sample accounting is
    /// defined on — and, when contention is enabled, at mid-segment
    /// job completions (a freed rectangle re-partitions link shares
    /// immediately, at its exact fractional time).
    fn advance_to(&mut self, target: f64) -> Result<(), FleetError> {
        let continuous = self.cfg.contention.is_some();
        while self.now < target {
            let cur_step = self.now.floor();
            let boundary = (cur_step + 1.0).min(target);
            let mut t1 = boundary;
            if continuous {
                for j in &self.running {
                    if j.rate <= 0.0 {
                        continue;
                    }
                    // A pause expiring mid-segment ends the link epoch
                    // (the job resumes charging its links).
                    if j.pause > 0.0 {
                        let tp = self.now + j.pause;
                        if tp > self.now && tp < t1 {
                            t1 = tp;
                        }
                    }
                    let eff = j.rate / j.dilation;
                    let remaining = j.spec.duration_steps as f64 - j.progress;
                    if eff <= 0.0 || remaining <= 0.0 {
                        continue;
                    }
                    let tc = self.now + j.pause + remaining / eff;
                    if tc > self.now && tc < t1 {
                        t1 = tc;
                    }
                }
            }
            let dt = t1 - self.now;
            if dt <= 0.0 {
                break; // fp safety: never spin in place
            }
            let paused_before = self.running.iter().filter(|j| j.pause > 0.0).count();
            let finished = self.advance_segment(dt);
            self.now = t1;
            self.step = cur_step as u64;
            let at_boundary = t1 == cur_step + 1.0;
            if at_boundary {
                for j in self.queue.iter_mut() {
                    j.waited += 1;
                }
                self.last_util = self.step_util_acc;
                self.last_good = self.step_good_acc;
                self.util_sum += self.last_util;
                self.goodput_sum += self.last_good;
                self.step_util_acc = 0.0;
                self.step_good_acc = 0.0;
            }
            let completed_any = !finished.is_empty();
            for i in finished.into_iter().rev() {
                let mut job = self.running.remove(i);
                if let Some(idx) = self.pidx.as_mut() {
                    let old = job.rect.expect("running job has a rectangle");
                    let _removed = idx.remove(&old);
                    debug_assert!(_removed, "completion releases an indexed rectangle");
                }
                job.completed_at = Some(t1.ceil() as u64);
                let (id, migrations) = (job.spec.id, job.migrations);
                self.log(format!("job {id} completes ({migrations} migrations)"));
                self.trace_job_span(&job);
                self.done.push(job);
            }
            let resumed = continuous
                && self.running.iter().filter(|j| j.pause > 0.0).count() < paused_before;
            if completed_any {
                self.try_admit()?;
                self.refresh_contention()?;
            } else if resumed {
                // A job's pause expired: it starts charging its links
                // again, so the fair shares must be re-split.
                self.refresh_contention()?;
            }
            if at_boundary {
                self.check_invariants()?;
                if self.step % self.sample_every == 0 {
                    self.sample();
                }
            }
        }
        Ok(())
    }

    /// The placement invariants, checked every fleet step.
    fn check_invariants(&self) -> Result<(), FleetError> {
        let fail = |violation: String| FleetError::Invariant { step: self.step, violation };
        let rects: Vec<Rect> = self.running.iter().map(|j| j.rect.expect("running")).collect();
        placer::check_rects(self.cfg.nx, self.cfg.ny, &rects)
            .map_err(|e| fail(e.to_string()))?;
        // The placement index must mirror failed regions + running
        // rectangles exactly (as a multiset; order is maintenance
        // history).
        if let Some(idx) = &self.pidx {
            let mut indexed = idx.obstacles().to_vec();
            let mut expected: Vec<Rect> = self.cluster.failed_regions().to_vec();
            expected.extend(rects.iter().copied());
            indexed.sort_unstable();
            expected.sort_unstable();
            if indexed != expected {
                return Err(fail(format!(
                    "placement index desynced: indexed {indexed:?} vs expected {expected:?}"
                )));
            }
        }
        // Every live-failure/job overlap must be a registered hole of
        // exactly that job.
        for f in self.cluster.failed_regions() {
            for j in &self.running {
                let r = j.rect.expect("running");
                if let Some(cut) = placer::intersect(&r, f) {
                    if !j.holes.contains(&cut) {
                        return Err(fail(format!(
                            "job {} at {r:?} overlaps failed {f:?} without a registered hole",
                            j.spec.id
                        )));
                    }
                }
            }
        }
        // Holes exist only inside their rectangle and over a live
        // failure.
        for j in &self.running {
            let r = j.rect.expect("running");
            for h in &j.holes {
                let inside = placer::intersect(&r, h) == Some(*h);
                let backed = self
                    .cluster
                    .failed_regions()
                    .iter()
                    .any(|f| placer::intersect(f, h) == Some(*h));
                if !inside || !backed {
                    return Err(fail(format!(
                        "job {} registers hole {h:?} not backed by a live failure in {r:?}",
                        j.spec.id
                    )));
                }
            }
        }
        Ok(())
    }

    fn sample(&mut self) {
        let max_dilation = self.running.iter().map(|j| j.dilation).fold(1.0f64, f64::max);
        self.samples.push(UtilSample {
            step: self.step,
            utilization: self.last_util,
            goodput: self.last_good,
            running: self.running.len(),
            queued: self.queue.len(),
            max_dilation,
        });
    }

    fn finish(mut self, label: String, arrivals: usize) -> (FleetRun, PlanCache) {
        let mut jobs: Vec<JobOutcome> = self
            .done
            .iter()
            .chain(self.running.iter())
            .chain(self.queue.iter())
            .map(Job::outcome)
            .collect();
        jobs.sort_by_key(|j| j.id);
        let jcts: Vec<f64> = jobs.iter().filter_map(|j| j.jct()).map(|x| x as f64).collect();
        let (mean_jct, median_jct) = mean_median(&jcts);
        let h = self.cfg.horizon.max(1) as f64;
        // Hotspot extraction: the sparse path scans only the charged
        // slots (after an ascending sort + dedup it visits exactly the
        // positive slots the dense scan would, in the same order); the
        // dense reference walks the whole mesh.
        let mut hot_idx: Vec<usize> = if self.cfg.sparse_occupancy {
            let mut touched = self.occ_touched.clone();
            touched.sort_unstable();
            touched.dedup();
            touched.into_iter().map(|s| s as usize).filter(|&s| self.link_occ[s] > 0.0).collect()
        } else {
            (0..self.link_occ.len()).filter(|&s| self.link_occ[s] > 0.0).collect()
        };
        hot_idx.sort_by(|&a, &b| self.link_occ[b].total_cmp(&self.link_occ[a]).then(a.cmp(&b)));
        let hotspots: Vec<LinkHotspot> = hot_idx
            .iter()
            .take(8)
            .map(|&s| {
                let node = s / 4;
                LinkHotspot {
                    x: node % self.cfg.nx,
                    y: node / self.cfg.nx,
                    dir: s % 4,
                    mean_occupancy: self.link_occ[s] / h,
                }
            })
            .collect();
        let mean_dilation = if self.dilation_weight > 0.0 {
            self.dilation_time / self.dilation_weight
        } else {
            1.0
        };
        // Satellite snapshot: top-N hotspot truncation is no longer
        // silent — the registry records how many candidates existed
        // and how many the cap dropped.
        self.reg.inc("hotspot_candidates", hot_idx.len() as u64);
        self.reg.inc("hotspot_dropped", hot_idx.len().saturating_sub(8) as u64);
        // Fold the scattered ad-hoc counters into the one snapshot:
        // summary counters, plan-cache delta, JCT histogram, and the
        // wall-clock profile phases as gauges.
        let cache_delta = self.cache.stats().delta(&self.stats_base);
        self.reg.inc("arrivals", arrivals as u64);
        self.reg.inc("completed", jcts.len() as u64);
        self.reg.inc("transitions", self.transitions);
        self.reg.inc("rewires", self.rewires);
        self.reg.inc("backfills", self.backfills);
        self.reg.inc("segments", self.segments);
        self.reg.inc("cache_hits", cache_delta.hits);
        self.reg.inc("cache_misses", cache_delta.misses);
        self.reg.inc("cache_full_compiles", cache_delta.full_compiles);
        self.reg.inc("cache_incremental_compiles", cache_delta.incremental_compiles);
        self.reg.inc("cache_evictions", cache_delta.evictions);
        for jct in &jcts {
            self.reg.observe("jct_steps", *jct);
        }
        // Serving aggregation. Every branch below is empty or gated on
        // `has_serving`, so a serving-free fleet reports the trivial
        // figures (attainment 1.0, p99 0.0) through untouched state.
        let (mut offered, mut met) = (0.0f64, 0.0f64);
        for j in &jobs {
            if j.class == JobClass::Serving {
                offered += j.requests;
                met += j.slo_met;
            }
        }
        let slo_attainment = if offered > 0.0 { (met / offered).clamp(0.0, 1.0) } else { 1.0 };
        let mut lat = std::mem::take(&mut self.serving_lat);
        let serving_p99_ms = weighted_latency_percentile(&mut lat, 0.99);
        if self.has_serving {
            // Per-class JCT: serving jobs never complete (horizon
            // lifetime), so the completed set is the training set.
            for jct in &jcts {
                self.reg.observe("jct_training_steps", *jct);
            }
            let n_serving = jobs.iter().filter(|j| j.class == JobClass::Serving).count();
            self.reg.inc("serving_jobs", n_serving as u64);
            // Serving jobs get their lifetime span at the horizon (they
            // have no completion to emit it from).
            if let Some(trace) = &self.cfg.trace {
                for j in &self.running {
                    if j.spec.class != JobClass::Serving {
                        continue;
                    }
                    let t0 = j.spec.arrival_step as f64 * STEP_US;
                    let dur = (self.cfg.horizon as f64 - j.spec.arrival_step as f64).max(0.0)
                        * STEP_US;
                    trace.span(
                        self.pid,
                        Self::job_tid(j.spec.id),
                        &format!("serving job {} ({}x{})", j.spec.id, j.spec.w, j.spec.h),
                        t0,
                        dur,
                        &[
                            ("requests", j.requests),
                            ("slo_met", j.slo_met),
                            ("migrations", j.migrations as f64),
                            ("ft_continues", j.ft_continues as f64),
                        ],
                    );
                }
            }
        }
        self.reg.set_gauge("profile_placement_s", self.prof.placement_s);
        self.reg.set_gauge("profile_contention_s", self.prof.contention_s);
        self.reg.set_gauge("profile_drain_s", self.prof.drain_s);
        self.reg.set_gauge("profile_executor_s", self.prof.executor_s);
        let run = FleetRun {
            label,
            summary: FleetSummary {
                horizon: self.cfg.horizon,
                arrivals,
                completed: jcts.len(),
                mean_jct,
                median_jct,
                mean_utilization: self.util_sum / h,
                goodput: self.goodput_sum / h,
                migrations: jobs.iter().map(|j| j.migrations).sum(),
                shrinks: jobs.iter().map(|j| j.shrinks).sum(),
                ft_continues: jobs.iter().map(|j| j.ft_continues).sum(),
                queue_waits: self.queue_waits,
                backfills: self.backfills,
                transitions: self.transitions,
                rewires: self.rewires,
                mean_dilation,
                max_dilation: self.max_dilation.max(1.0),
                contention_epochs: self.contention_epochs,
                segments: self.segments,
                cache: self.cache.stats().delta(&self.stats_base),
                slo_attainment,
                serving_p99_ms,
                preemptions: self.preemptions,
            },
            jobs,
            samples: self.samples,
            hotspots,
            events: self.events_log,
            profile: self.prof,
            metrics: self.reg,
        };
        // The warmed cache outlives this run (persistence, seed for
        // the next policy); don't let it keep this run's trace sink.
        self.cache.set_trace(None, 0);
        (run, self.cache)
    }
}

/// One entry of the wall-clock engine's global event timeline.
/// Cluster events sort before arrivals at equal times (matching the
/// round-robin loop's per-step order), `seq` preserves source order
/// within a kind.
#[derive(Debug)]
struct WallEntry {
    time: f64,
    rank: u8,
    seq: u64,
    kind: WallKind,
}

#[derive(Debug)]
enum WallKind {
    Cluster(ClusterEvent),
    Arrival(JobSpec),
}

impl PartialEq for WallEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for WallEntry {}

impl PartialOrd for WallEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for WallEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.rank.cmp(&other.rank))
            .then(self.seq.cmp(&other.seq))
    }
}

/// Run one seeded fleet simulation. Errors on the first placement-
/// invariant violation (the CI gate), clock regression, or invalid
/// scripted event.
pub fn run_fleet(cfg: &FleetConfig) -> Result<FleetRun, FleetError> {
    Ok(run_with_cache(cfg)?.0)
}

/// [`run_fleet`], also returning the warmed plan cache — the fleet
/// binary persists it so the next process (fleet or sweep) warm-starts.
pub fn run_with_cache(cfg: &FleetConfig) -> Result<(FleetRun, PlanCache), FleetError> {
    let label = cfg.policy.map(|p| p.name().to_string()).unwrap_or_else(|| "mixed".to_string());
    let mut specs = cfg.workload.generate();
    if let Some(p) = cfg.policy {
        for s in &mut specs {
            s.policy = p;
        }
    }
    for s in &specs {
        let fits = (s.w <= cfg.nx && s.h <= cfg.ny) || (s.h <= cfg.nx && s.w <= cfg.ny);
        if !fits || s.w == 0 || s.h == 0 {
            return Err(FleetError::Unplaceable(s.id, s.w, s.h));
        }
    }
    let arrivals = specs.len();
    let mut timeline = cfg.events.clone();
    let mut site_pick_s = 0.0;
    if let Some(m) = &cfg.mtbf {
        let t0 = Instant::now();
        // Failures strike the *physical* mesh — spare rows/columns are
        // just as mortal as the logical rectangle they protect.
        let (gx, gy) = cfg.phys_dims();
        timeline.extend(m.generate(gx, gy, cfg.horizon));
        site_pick_s = t0.elapsed().as_secs_f64();
    }
    let (mut run, cache) = match cfg.clock {
        ClockMode::RoundRobin => run_round_robin(cfg, label, specs, timeline, arrivals),
        ClockMode::WallClock => run_wall_clock(cfg, label, specs, timeline, arrivals),
    }?;
    run.profile.site_pick_s = site_pick_s;
    run.metrics.set_gauge("profile_site_pick_s", site_pick_s);
    Ok((run, cache))
}

/// The legacy single-clock loop (the differential reference).
fn run_round_robin(
    cfg: &FleetConfig,
    label: String,
    specs: Vec<JobSpec>,
    timeline: Vec<TimedEvent>,
    arrivals: usize,
) -> Result<(FleetRun, PlanCache), FleetError> {
    let mut events = EventQueue::new(timeline);
    let mut pending: VecDeque<JobSpec> = specs.into();
    let mut fleet = Fleet::new(cfg);
    fleet.has_serving = pending.iter().any(|s| s.class == JobClass::Serving);
    if let Some(trace) = &cfg.trace {
        fleet.pid = trace.alloc_pid(&format!("fleet {label} {}x{} rr", cfg.nx, cfg.ny));
        fleet.cache.set_trace(Some(trace.clone()), fleet.pid);
    }

    for step in 0..cfg.horizon {
        fleet.step = step;
        while let Some(ev) = events.pop_due(step) {
            fleet.handle_event(ev)?;
        }
        while pending.front().is_some_and(|s| s.arrival_step <= step) {
            let spec = pending.pop_front().expect("front checked");
            fleet.log(arrival_message(&spec));
            fleet.queue.push_back(Job::new(spec));
        }
        fleet.try_admit()?;
        if fleet.advance() {
            fleet.try_admit()?;
        }
        fleet.check_invariants()?;
        if step % fleet.sample_every == 0 {
            fleet.sample();
        }
    }
    Ok(fleet.finish(label, arrivals))
}

/// The event-driven wall-clock engine: cluster events and arrivals
/// merge into one time-ordered timeline; between events, jobs
/// integrate progress on their own (possibly contention-dilated)
/// timelines. The timeline is fixed before the loop starts (nothing
/// is ever inserted mid-run), so it is sorted once and drained with a
/// cursor — every same-instant batch comes off in one pass with no
/// per-event heap maintenance. `WallEntry`'s total order (time, rank,
/// seq with unique seq) makes the sorted order identical to the heap
/// pop order it replaced.
fn run_wall_clock(
    cfg: &FleetConfig,
    label: String,
    specs: Vec<JobSpec>,
    timeline: Vec<TimedEvent>,
    arrivals: usize,
) -> Result<(FleetRun, PlanCache), FleetError> {
    let mut entries: Vec<WallEntry> = Vec::new();
    let mut seq = 0u64;
    // From the full spec list (not the horizon-filtered entries), so
    // both engines agree even on degenerate beyond-horizon arrivals.
    let has_serving = specs.iter().any(|s| s.class == JobClass::Serving);
    // Drain through EventQueue so equal-time cluster events keep the
    // exact stable order the round-robin loop replays.
    let mut events = EventQueue::new(timeline);
    while let Some(ev) = events.pop_due(u64::MAX) {
        if ev.at_step >= cfg.horizon {
            continue;
        }
        entries.push(WallEntry {
            time: ev.at_step as f64,
            rank: 0,
            seq,
            kind: WallKind::Cluster(ev.event),
        });
        seq += 1;
    }
    for spec in specs {
        if spec.arrival_step >= cfg.horizon {
            continue;
        }
        entries.push(WallEntry {
            time: spec.arrival_step as f64,
            rank: 1,
            seq,
            kind: WallKind::Arrival(spec),
        });
        seq += 1;
    }
    entries.sort_unstable();

    let mut fleet = Fleet::new(cfg);
    fleet.has_serving = has_serving;
    if let Some(trace) = &cfg.trace {
        fleet.pid = trace.alloc_pid(&format!("fleet {label} {}x{} wall", cfg.nx, cfg.ny));
        fleet.cache.set_trace(Some(trace.clone()), fleet.pid);
    }
    let horizon = cfg.horizon as f64;
    let mut it = entries.into_iter().peekable();
    loop {
        let Some(entry) = it.next() else { break };
        let t = entry.time;
        if t < fleet.now {
            return Err(FleetError::Invariant {
                step: fleet.now as u64,
                violation: format!("global event clock regressed: {t} < {}", fleet.now),
            });
        }
        fleet.advance_to(t)?;
        fleet.step = t as u64;
        apply_entry(&mut fleet, entry)?;
        // Batch every same-time entry before admission so a multi-event
        // instant behaves exactly like one round-robin step.
        while it.peek().is_some_and(|e| e.time == t) {
            let e = it.next().expect("peeked");
            apply_entry(&mut fleet, e)?;
        }
        fleet.try_admit()?;
        fleet.refresh_contention()?;
    }
    fleet.advance_to(horizon)?;
    Ok(fleet.finish(label, arrivals))
}

fn apply_entry(fleet: &mut Fleet<'_>, entry: WallEntry) -> Result<(), FleetError> {
    match entry.kind {
        WallKind::Cluster(event) => {
            fleet.handle_event(TimedEvent { at_step: entry.time as u64, event })
        }
        WallKind::Arrival(spec) => {
            fleet.log(arrival_message(&spec));
            fleet.queue.push_back(Job::new(spec));
            Ok(())
        }
    }
}

/// Run the same seeded fleet once per policy override — the
/// per-policy utilization/JCT/goodput comparison `BENCH_fleet.json`
/// records.
pub fn compare_policies(
    cfg: &FleetConfig,
    policies: &[JobPolicy],
) -> Result<Vec<FleetRun>, FleetError> {
    let mut out = Vec::with_capacity(policies.len());
    for &p in policies {
        let mut c = cfg.clone();
        c.policy = Some(p);
        out.push(run_fleet(&c)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterEvent;

    fn tiny_cfg() -> FleetConfig {
        let mut cfg = FleetConfig::quick();
        cfg.nx = 8;
        cfg.ny = 8;
        cfg.horizon = 160;
        cfg.payload = 1 << 12;
        cfg.mtbf = None;
        cfg.workload = WorkloadModel {
            seed: 5,
            jobs: 2,
            mean_interarrival_steps: 1.0,
            mean_duration_steps: 40.0,
            min_duration_steps: 120,
            shapes: vec![(4, 4)],
            policies: vec![JobPolicy::Continue],
            scripted: Vec::new(),
            serving: None,
        };
        cfg
    }

    fn fail_at(at_step: u64, r: Rect) -> TimedEvent {
        TimedEvent { at_step, event: ClusterEvent::Fail(r) }
    }

    fn repair_at(at_step: u64, r: Rect) -> TimedEvent {
        TimedEvent { at_step, event: ClusterEvent::Repair(r) }
    }

    #[test]
    fn fleet_run_is_deterministic() {
        let mut cfg = tiny_cfg();
        cfg.events = vec![fail_at(40, Rect::new(0, 0, 2, 2)), repair_at(90, Rect::new(0, 0, 2, 2))];
        cfg.policy = Some(JobPolicy::Adaptive);
        let a = run_fleet(&cfg).unwrap();
        let b = run_fleet(&cfg).unwrap();
        assert_eq!(a.summary.goodput.to_bits(), b.summary.goodput.to_bits());
        assert_eq!(a.summary.mean_utilization.to_bits(), b.summary.mean_utilization.to_bits());
        assert_eq!(a.summary.migrations, b.summary.migrations);
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.completed_at, y.completed_at);
        }
    }

    #[test]
    fn clock_mode_names_roundtrip() {
        for c in ClockMode::ALL {
            assert_eq!(ClockMode::parse(c.name()), Some(c));
        }
        assert_eq!(ClockMode::parse("wall"), Some(ClockMode::WallClock));
        assert_eq!(ClockMode::parse("rr"), Some(ClockMode::RoundRobin));
        assert_eq!(ClockMode::parse("??"), None);
    }

    #[test]
    fn wall_clock_without_contention_matches_round_robin() {
        // The in-module smoke version of the differential contract
        // (`rust/tests/fleet_async.rs` runs the multi-seed version):
        // same config, both engines, bit-identical trace.
        let mut cfg = tiny_cfg();
        cfg.events = vec![fail_at(40, Rect::new(0, 0, 2, 2)), repair_at(90, Rect::new(0, 0, 2, 2))];
        cfg.policy = Some(JobPolicy::Adaptive);
        let rr = run_fleet(&cfg).unwrap();
        cfg.clock = ClockMode::WallClock;
        let wall = run_fleet(&cfg).unwrap();
        assert_eq!(rr.events, wall.events, "placement trace must match bit-for-bit");
        assert_eq!(rr.summary.goodput.to_bits(), wall.summary.goodput.to_bits());
        assert_eq!(
            rr.summary.mean_utilization.to_bits(),
            wall.summary.mean_utilization.to_bits()
        );
        assert_eq!(rr.samples.len(), wall.samples.len());
        for (x, y) in rr.jobs.iter().zip(&wall.jobs) {
            assert_eq!(x.completed_at, y.completed_at);
            assert_eq!(x.waited_steps, y.waited_steps);
        }
    }

    #[test]
    fn sparse_occupancy_matches_dense_reference() {
        // In-module smoke version of the scale differential
        // (`rust/tests/scale_equivalence.rs` runs the multi-seed
        // version): load memoization, epoch skips and touched-slot
        // hotspot extraction must not change a single bit.
        let mut dense = tiny_cfg();
        dense.clock = ClockMode::WallClock;
        dense.contention = Some(ContentionModel::stressed());
        dense.events =
            vec![fail_at(40, Rect::new(0, 0, 2, 2)), repair_at(90, Rect::new(0, 0, 2, 2))];
        dense.policy = Some(JobPolicy::Adaptive);
        dense.sparse_occupancy = false;
        let mut sparse = dense.clone();
        sparse.sparse_occupancy = true;
        let a = run_fleet(&dense).unwrap();
        let b = run_fleet(&sparse).unwrap();
        assert_eq!(a.events, b.events, "event trace must match bit-for-bit");
        assert_eq!(a.summary.goodput.to_bits(), b.summary.goodput.to_bits());
        assert_eq!(a.summary.mean_dilation.to_bits(), b.summary.mean_dilation.to_bits());
        assert_eq!(a.summary.max_dilation.to_bits(), b.summary.max_dilation.to_bits());
        assert_eq!(a.summary.contention_epochs, b.summary.contention_epochs);
        assert_eq!(a.summary.segments, b.summary.segments);
        assert_eq!(a.hotspots.len(), b.hotspots.len());
        for (x, y) in a.hotspots.iter().zip(&b.hotspots) {
            assert_eq!((x.x, x.y, x.dir), (y.x, y.y, y.dir));
            assert_eq!(x.mean_occupancy.to_bits(), y.mean_occupancy.to_bits());
        }
    }

    #[test]
    fn fast_placer_matches_dense_scan_reference() {
        // In-module smoke version of the placement-index differential
        // (`rust/tests/fleet_placement.rs` runs the property version):
        // the incremental index and the full obstacle rescan must
        // produce bit-identical fleets, including queue-waits and
        // defragmentation.
        let mut dense = tiny_cfg();
        dense.mtbf = Some(MtbfModel::board(9, 25.0, 40.0));
        dense.policy = Some(JobPolicy::Adaptive);
        dense.backfill = true;
        dense.fast_placer = false;
        let mut fast = dense.clone();
        fast.fast_placer = true;
        let a = run_fleet(&dense).unwrap();
        let b = run_fleet(&fast).unwrap();
        assert_eq!(a.events, b.events, "placement trace must match bit-for-bit");
        assert_eq!(a.summary.goodput.to_bits(), b.summary.goodput.to_bits());
        assert_eq!(a.summary.mean_utilization.to_bits(), b.summary.mean_utilization.to_bits());
        assert_eq!(a.summary.migrations, b.summary.migrations);
        assert_eq!(a.summary.queue_waits, b.summary.queue_waits);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.completed_at, y.completed_at);
            assert_eq!(x.waited_steps, y.waited_steps);
        }
    }

    #[test]
    fn continue_vs_migrate_changes_goodput_measurably() {
        // Scripted failure inside job 0's deterministic bottom-left
        // placement: continue-FT keeps 12 workers on a degraded 4x4
        // (the same board-on-4x4 geometry the coordinator tests prove
        // schedulable); migrate restarts 16 workers elsewhere paying
        // rollback. The trajectories must diverge — the arbitration
        // signal.
        let mut cfg = tiny_cfg();
        cfg.events = vec![fail_at(50, Rect::new(2, 0, 2, 2)), repair_at(130, Rect::new(2, 0, 2, 2))];
        let runs =
            compare_policies(&cfg, &[JobPolicy::Continue, JobPolicy::Migrate, JobPolicy::Adaptive])
                .unwrap();
        let good: Vec<f64> = runs.iter().map(|r| r.summary.goodput).collect();
        assert!(good.iter().all(|&g| g > 0.0), "{good:?}");
        let (c, m, a) = (good[0], good[1], good[2]);
        assert!((c - m).abs() > 1e-9, "policies must differ measurably: {c} vs {m}");
        assert!(a + 1e-9 >= c.min(m), "adaptive no worse than the worst static: {a} vs {c}/{m}");
        // The continue run trained through the hole; the migrate run
        // moved.
        assert!(runs[0].summary.ft_continues > 0);
        assert!(runs[1].summary.migrations > 0);
    }

    #[test]
    fn wait_policy_queues_and_readmits() {
        let mut cfg = tiny_cfg();
        cfg.policy = Some(JobPolicy::Wait);
        // Fail inside job 0's rectangle, repair later; the job must
        // requeue and eventually be re-admitted.
        cfg.events = vec![fail_at(30, Rect::new(0, 0, 2, 2)), repair_at(60, Rect::new(0, 0, 2, 2))];
        let run = run_fleet(&cfg).unwrap();
        assert!(run.summary.queue_waits > 0);
        assert!(run.events.iter().any(|(_, e)| e.contains("releases its rectangle")));
        // Re-admission happened (two placements of job 0).
        let placements =
            run.events.iter().filter(|(_, e)| e.starts_with("job 0 placed")).count();
        assert!(placements >= 2, "events: {:?}", run.events);
    }

    #[test]
    fn reconfigure_without_spares_matches_continue() {
        // Containment: with no spares provisioned, Reconfigure's
        // degraded ladder IS continue-FT — the runs must be
        // bit-identical (satellite: graceful degradation).
        let mut cfg = tiny_cfg();
        cfg.events = vec![fail_at(40, Rect::new(0, 0, 2, 2)), repair_at(90, Rect::new(0, 0, 2, 2))];
        cfg.policy = Some(JobPolicy::Continue);
        let cont = run_fleet(&cfg).unwrap();
        cfg.policy = Some(JobPolicy::Reconfigure);
        let reco = run_fleet(&cfg).unwrap();
        assert_eq!(cont.events, reco.events, "trace must match bit-for-bit");
        assert_eq!(cont.summary.goodput.to_bits(), reco.summary.goodput.to_bits());
        assert_eq!(
            cont.summary.mean_utilization.to_bits(),
            reco.summary.mean_utilization.to_bits()
        );
        assert_eq!(cont.summary.rewires, 0);
        assert_eq!(reco.summary.rewires, 0);
    }

    #[test]
    fn spared_fleet_heals_then_degrades_when_spares_run_out() {
        // 8x8 logical + 2 spare columns (10x8 physical). First board
        // failure retires two physical columns — the heal absorbs it
        // and no job sees a hole. The second and third failures exceed
        // the spare budget, so their logical images surface and the
        // Reconfigure jobs degrade gracefully to continue-FT. The run
        // must complete (invariants are Err-checked every step).
        let mut cfg = tiny_cfg();
        cfg.spare_cols = 2;
        cfg.policy = Some(JobPolicy::Reconfigure);
        cfg.events = vec![
            fail_at(30, Rect::new(0, 0, 2, 2)),
            fail_at(70, Rect::new(4, 0, 2, 2)),
            fail_at(100, Rect::new(6, 4, 2, 2)),
        ];
        let run = run_fleet(&cfg).unwrap();
        assert_eq!(run.summary.rewires, 1, "events: {:?}", run.events);
        assert!(run.events.iter().any(|(_, e)| e.starts_with("reconfigured")));
        // The healed failure never surfaced as a logical hole (the
        // only x0:0 fail line is the physical one)...
        assert!(!run.events.iter().any(|(_, e)| e.starts_with("fail")
            && e.contains("x0: 0,")
            && !e.contains("physical")));
        // ...but the over-budget ones did, and FT absorbed them.
        assert!(run.summary.ft_continues > 0, "events: {:?}", run.events);
        assert!(run.summary.goodput > 0.0);
    }

    #[test]
    fn spared_fleet_run_is_deterministic() {
        let mut cfg = tiny_cfg();
        cfg.spare_cols = 2;
        cfg.spare_rows = 2;
        cfg.policy = Some(JobPolicy::Adaptive);
        cfg.mtbf = Some(MtbfModel::board(11, 25.0, 40.0));
        let a = run_fleet(&cfg).unwrap();
        let b = run_fleet(&cfg).unwrap();
        assert_eq!(a.events, b.events);
        assert_eq!(a.summary.goodput.to_bits(), b.summary.goodput.to_bits());
        assert_eq!(a.summary.rewires, b.summary.rewires);
        assert_eq!(a.summary.transitions, b.summary.transitions);
    }

    #[test]
    fn quick_fleet_satisfies_acceptance_shape() {
        // ≥4 concurrent jobs on a 16x32 mesh under an MTBF timeline
        // with repairs: completes with zero invariant violations (any
        // violation is an Err), non-trivial utilization, and cache
        // sharing across jobs.
        let mut cfg = FleetConfig::quick();
        cfg.horizon = 240;
        cfg.payload = 1 << 12;
        // Dense failure process so the fixed seed certainly produces
        // fail + repair events inside the reduced horizon.
        cfg.mtbf = Some(MtbfModel::board(7, 20.0, 10.0));
        let run = run_fleet(&cfg).unwrap();
        assert!(run.summary.arrivals >= 4);
        assert!(run.summary.mean_utilization > 0.1, "{:?}", run.summary);
        assert!(run.summary.goodput > 0.0);
        let s = &run.summary.cache;
        assert!(s.hits > 0, "jobs with equal shapes must share plans: {s:?}");
        // The MTBF timeline contains repairs within the horizon.
        assert!(run.events.iter().any(|(_, e)| e.starts_with("fail")));
    }
}
