//! 2-D rectangle placement over the obstacle boundary grid.
//!
//! Obstacles are failed regions *and* already-placed job rectangles —
//! both are axis-aligned rectangles, so [`FailedRegion`]'s geometry is
//! reused as the [`Rect`] type. Two primitives:
//!
//! - [`place`] — bottom-left placement of a `w x h` rectangle. The
//!   candidate corner set is drawn from the obstacle boundary grid
//!   (mesh edges, obstacle right/top edges, obstacle left/bottom edges
//!   minus the rectangle size) snapped to even coordinates, which is
//!   *complete* for even placements: pushing any valid placement down
//!   then left (in steps of two) stops on a boundary-grid candidate.
//!   Even snapping keeps every future in-rectangle failed region
//!   even-aligned in the job's local coordinates — the fault-tolerant
//!   planner's precondition (paper Fig 8).
//! - [`largest_clear_rect`] — exact maximum-empty-rectangle over the
//!   boundary grid (every maximal empty rectangle has its edges on
//!   obstacle boundaries or the mesh edge). `largest_submesh` in
//!   `coordinator::policy` is the failed-regions-only special case and
//!   delegates here. The default implementation answers each candidate
//!   clearance with an O(1) blocked-cell prefix-sum query over the
//!   compressed boundary grid; [`largest_clear_rect_scan`] keeps the
//!   per-candidate obstacle scan as the bit-identical dense reference.
//! - [`PlacementIndex`] — a persistent incremental form of the same
//!   obstacle set for the fleet's per-event placement queries
//!   (`FleetConfig::fast_placer`). Obstacles are maintained across
//!   place/free/fail/repair in a partition of the mesh into y-strips,
//!   each holding the sorted x-intervals of the obstacles crossing it,
//!   so an update touches only the affected strips and a clearance
//!   probe walks only the strips the candidate rectangle spans —
//!   instead of rebuilding the obstacle list and scanning all of it on
//!   every query. Queries are bit-identical to the scan-based [`place`]
//!   / [`place_oriented`] / [`largest_clear_rect`] over the same
//!   obstacle multiset (`rust/tests/fleet_placement.rs` holds the
//!   differential property suite).

use crate::mesh::FailedRegion;
use thiserror::Error;

/// Axis-aligned rectangle on the cluster mesh (`x0`, `y0`, `w`, `h`).
pub type Rect = FailedRegion;

/// A violated placement invariant (see the module docs of
/// [`crate::sched`]).
#[derive(Debug, Error, PartialEq, Eq)]
pub enum PlacementViolation {
    #[error("rectangle {0:?} leaves the {1}x{2} mesh")]
    OutOfBounds(Rect, usize, usize),
    #[error("rectangles {0:?} and {1:?} overlap")]
    Overlap(Rect, Rect),
}

/// Bounds + pairwise-disjointness check over a set of placed
/// rectangles.
pub fn check_rects(nx: usize, ny: usize, rects: &[Rect]) -> Result<(), PlacementViolation> {
    for (i, r) in rects.iter().enumerate() {
        if r.x1() > nx || r.y1() > ny {
            return Err(PlacementViolation::OutOfBounds(*r, nx, ny));
        }
        if let Some(other) = rects[i + 1..].iter().find(|o| o.overlaps(r)) {
            return Err(PlacementViolation::Overlap(*r, *other));
        }
    }
    Ok(())
}

/// Intersection of two rectangles, if non-empty.
pub fn intersect(a: &Rect, b: &Rect) -> Option<Rect> {
    let x0 = a.x0.max(b.x0);
    let y0 = a.y0.max(b.y0);
    let x1 = a.x1().min(b.x1());
    let y1 = a.y1().min(b.y1());
    if x0 < x1 && y0 < y1 {
        Some(Rect::new(x0, y0, x1 - x0, y1 - y0))
    } else {
        None
    }
}

/// Translate `r` (cluster coords, fully inside `rect`) into `rect`'s
/// local coordinates.
pub fn to_local(rect: &Rect, r: &Rect) -> Rect {
    debug_assert!(r.x0 >= rect.x0 && r.y0 >= rect.y0 && r.x1() <= rect.x1() && r.y1() <= rect.y1());
    Rect::new(r.x0 - rect.x0, r.y0 - rect.y0, r.w, r.h)
}

/// Translate `r` from `rect`'s local coordinates back to cluster
/// coordinates.
pub fn to_cluster(rect: &Rect, r: &Rect) -> Rect {
    Rect::new(rect.x0 + r.x0, rect.y0 + r.y0, r.w, r.h)
}

fn even_up(v: usize) -> usize {
    v + (v & 1)
}

fn even_down(v: usize) -> usize {
    v & !1usize
}

/// Bottom-left placement of a `w x h` rectangle avoiding every
/// obstacle, restricted to even-aligned positions. Returns the
/// placement with minimal `(y0, x0)`, or `None` when no even-aligned
/// position fits.
pub fn place(nx: usize, ny: usize, obstacles: &[Rect], w: usize, h: usize) -> Option<Rect> {
    if w == 0 || h == 0 || w > nx || h > ny {
        return None;
    }
    let mut xs: Vec<usize> = vec![0, even_down(nx - w)];
    let mut ys: Vec<usize> = vec![0, even_down(ny - h)];
    for ob in obstacles {
        xs.push(even_up(ob.x1()));
        xs.push(even_down(ob.x0.saturating_sub(w)));
        ys.push(even_up(ob.y1()));
        ys.push(even_down(ob.y0.saturating_sub(h)));
    }
    xs.retain(|&x| x + w <= nx);
    ys.retain(|&y| y + h <= ny);
    xs.sort_unstable();
    xs.dedup();
    ys.sort_unstable();
    ys.dedup();
    for &y in &ys {
        for &x in &xs {
            let r = Rect::new(x, y, w, h);
            if obstacles.iter().all(|ob| !ob.overlaps(&r)) {
                return Some(r);
            }
        }
    }
    None
}

/// [`place`] trying both orientations (`w x h` first, then rotated);
/// when both fit, the lower `(y0, x0)` corner wins, ties preferring
/// the requested orientation.
pub fn place_oriented(
    nx: usize,
    ny: usize,
    obstacles: &[Rect],
    w: usize,
    h: usize,
) -> Option<Rect> {
    let a = place(nx, ny, obstacles, w, h);
    if w == h {
        return a;
    }
    let b = place(nx, ny, obstacles, h, w);
    match (a, b) {
        (Some(ra), Some(rb)) => {
            if (rb.y0, rb.x0) < (ra.y0, ra.x0) {
                Some(rb)
            } else {
                Some(ra)
            }
        }
        (a, b) => a.or(b),
    }
}

/// Largest axis-aligned clear rectangle of `nx x ny` avoiding **all**
/// `obstacles`, as `(x0, y0, w, h)`. Ties prefer more chips, then
/// wider shapes. With no obstacles the answer is the full mesh.
///
/// The candidate edges are drawn from the obstacle boundary grid
/// (every maximal empty rectangle has its edges on obstacle boundaries
/// or the mesh edge), so the result is exact for any number of
/// disjoint rectangular obstacles.
pub fn largest_clear_rect(
    nx: usize,
    ny: usize,
    obstacles: &[Rect],
) -> (usize, usize, usize, usize) {
    let mut xs = vec![0, nx];
    let mut ys = vec![0, ny];
    for r in obstacles {
        xs.push(r.x0.min(nx));
        xs.push(r.x1().min(nx));
        ys.push(r.y0.min(ny));
        ys.push(r.y1().min(ny));
    }
    xs.sort_unstable();
    xs.dedup();
    ys.sort_unstable();
    ys.dedup();

    // Every obstacle edge is a compressed-grid line, so each obstacle
    // (clipped to the mesh) covers whole compressed cells and a
    // candidate is clear iff its blocked-cell count is zero — an O(1)
    // prefix-sum query replacing the per-candidate obstacle scan of
    // [`largest_clear_rect_scan`]. Candidate order and the
    // strictly-greater `(area, width)` key are identical, so the
    // winner matches the scan bit for bit.
    let cw = xs.len() - 1;
    let ch = ys.len() - 1;
    let mut blocked = vec![0i64; cw * ch];
    for r in obstacles {
        let ix0 = xs.partition_point(|&v| v < r.x0.min(nx));
        let ix1 = xs.partition_point(|&v| v < r.x1().min(nx));
        let iy0 = ys.partition_point(|&v| v < r.y0.min(ny));
        let iy1 = ys.partition_point(|&v| v < r.y1().min(ny));
        for cell_y in iy0..iy1 {
            for cell_x in ix0..ix1 {
                blocked[cell_y * cw + cell_x] = 1;
            }
        }
    }
    // pre[j * (cw + 1) + i] = blocked cells in [0, i) x [0, j).
    let mut pre = vec![0i64; (cw + 1) * (ch + 1)];
    for cell_y in 0..ch {
        for cell_x in 0..cw {
            pre[(cell_y + 1) * (cw + 1) + cell_x + 1] = blocked[cell_y * cw + cell_x]
                + pre[cell_y * (cw + 1) + cell_x + 1]
                + pre[(cell_y + 1) * (cw + 1) + cell_x]
                - pre[cell_y * (cw + 1) + cell_x];
        }
    }
    let blocked_in = |i0: usize, i1: usize, j0: usize, j1: usize| {
        pre[j1 * (cw + 1) + i1] + pre[j0 * (cw + 1) + i0]
            - pre[j0 * (cw + 1) + i1]
            - pre[j1 * (cw + 1) + i0]
    };

    let mut best = (0, 0, 0, 0);
    let mut best_key = (0usize, 0usize);
    for (i, &x0) in xs.iter().enumerate() {
        for (di, &x1) in xs[i + 1..].iter().enumerate() {
            for (j, &y0) in ys.iter().enumerate() {
                for (dj, &y1) in ys[j + 1..].iter().enumerate() {
                    if blocked_in(i, i + 1 + di, j, j + 1 + dj) > 0 {
                        continue;
                    }
                    let (w, h) = (x1 - x0, y1 - y0);
                    let key = (w * h, w);
                    if key > best_key {
                        best_key = key;
                        best = (x0, y0, w, h);
                    }
                }
            }
        }
    }
    best
}

/// The dense reference for [`largest_clear_rect`]: the same boundary
/// grid and candidate order, with each candidate's clearance answered
/// by a full obstacle scan. Kept for the differential property suite
/// (`rust/tests/fleet_placement.rs`); the two are bit-identical on any
/// obstacle multiset.
pub fn largest_clear_rect_scan(
    nx: usize,
    ny: usize,
    obstacles: &[Rect],
) -> (usize, usize, usize, usize) {
    let mut xs = vec![0, nx];
    let mut ys = vec![0, ny];
    for r in obstacles {
        xs.push(r.x0.min(nx));
        xs.push(r.x1().min(nx));
        ys.push(r.y0.min(ny));
        ys.push(r.y1().min(ny));
    }
    xs.sort_unstable();
    xs.dedup();
    ys.sort_unstable();
    ys.dedup();

    let clear = |x0: usize, y0: usize, x1: usize, y1: usize| {
        let candidate = Rect::new(x0, y0, x1 - x0, y1 - y0);
        obstacles.iter().all(|r| !r.overlaps(&candidate))
    };

    let mut best = (0, 0, 0, 0);
    let mut best_key = (0usize, 0usize);
    for (i, &x0) in xs.iter().enumerate() {
        for &x1 in &xs[i + 1..] {
            for (j, &y0) in ys.iter().enumerate() {
                for &y1 in &ys[j + 1..] {
                    if !clear(x0, y0, x1, y1) {
                        continue;
                    }
                    let (w, h) = (x1 - x0, y1 - y0);
                    let key = (w * h, w);
                    if key > best_key {
                        best_key = key;
                        best = (x0, y0, w, h);
                    }
                }
            }
        }
    }
    best
}

/// Largest *even-aligned, even-sized* sub-rectangle of a local clear
/// rectangle: origin rounded up to even, dims rounded down. `None`
/// when fewer than 2x2 chips remain — the smallest schedulable
/// sub-mesh.
pub fn even_shrink(r: &Rect) -> Option<Rect> {
    let x0 = even_up(r.x0);
    let y0 = even_up(r.y0);
    if x0 >= r.x1() || y0 >= r.y1() {
        return None;
    }
    let w = even_down(r.x1() - x0);
    let h = even_down(r.y1() - y0);
    if w < 2 || h < 2 {
        return None;
    }
    Some(Rect::new(x0, y0, w, h))
}

/// One y-strip of the [`PlacementIndex`]: the half-open row band
/// `[y0, y1)` and the x-intervals of every obstacle crossing it,
/// sorted by `(x0, x1)`. The intervals form a *multiset* and may
/// overlap each other — the fleet's obstacle set mixes failed regions
/// with job rectangles, and a failed region can sit inside a running
/// job's rectangle.
#[derive(Debug, Clone)]
struct Strip {
    y0: usize,
    y1: usize,
    xs: Vec<(usize, usize)>,
}

/// Persistent incremental obstacle index for placement queries.
///
/// Maintains the obstacle multiset across place/free/fail/repair with
/// O(affected strips) updates: the mesh's y-range is partitioned into
/// strips whose boundaries are exactly the y-edges of obstacles ever
/// added, and each strip holds the sorted x-intervals of the obstacles
/// crossing it. Strips are only ever split (never re-merged), so a
/// removal finds its intervals in precisely the strips its insertion
/// wrote — the strip count stays bounded by the mesh height.
///
/// [`PlacementIndex::place`], [`PlacementIndex::place_oriented`] and
/// [`PlacementIndex::largest_clear_rect`] are bit-identical to the
/// scan-based free functions over [`PlacementIndex::obstacles`]: the
/// candidate corner set is derived from the same obstacle multiset
/// (sorted + deduped, so construction order is irrelevant) and the
/// strip walk answers exactly the all-obstacles disjointness predicate
/// the scan evaluates.
#[derive(Debug, Clone)]
pub struct PlacementIndex {
    nx: usize,
    ny: usize,
    obstacles: Vec<Rect>,
    /// Partition of `[0, ny)`, ascending and contiguous.
    strips: Vec<Strip>,
}

impl PlacementIndex {
    /// Empty index over an `nx x ny` mesh.
    pub fn new(nx: usize, ny: usize) -> Self {
        let strips =
            if ny > 0 { vec![Strip { y0: 0, y1: ny, xs: Vec::new() }] } else { Vec::new() };
        Self { nx, ny, obstacles: Vec::new(), strips }
    }

    /// The current obstacle multiset (arbitrary order).
    pub fn obstacles(&self) -> &[Rect] {
        &self.obstacles
    }

    /// Split the strip containing `y` so that `y` becomes a strip
    /// boundary. No-op when it already is one (or lies outside the
    /// mesh).
    fn split_at(&mut self, y: usize) {
        if y == 0 || y >= self.ny {
            return;
        }
        if let Some(i) = self.strips.iter().position(|s| s.y0 < y && y < s.y1) {
            let upper_xs = self.strips[i].xs.clone();
            let upper_y1 = self.strips[i].y1;
            self.strips[i].y1 = y;
            self.strips.insert(i + 1, Strip { y0: y, y1: upper_y1, xs: upper_xs });
        }
    }

    /// Add one obstacle. O(affected strips).
    pub fn add(&mut self, r: &Rect) {
        debug_assert!(
            r.x1() <= self.nx && r.y1() <= self.ny,
            "obstacle {r:?} leaves the {}x{} mesh",
            self.nx,
            self.ny
        );
        self.obstacles.push(*r);
        self.split_at(r.y0);
        self.split_at(r.y1());
        let iv = (r.x0, r.x1());
        for s in self.strips.iter_mut() {
            // After splitting, every strip is fully inside or fully
            // outside the obstacle's row range.
            if s.y0 >= r.y0 && s.y1 <= r.y1() {
                let pos = s.xs.partition_point(|&e| e < iv);
                s.xs.insert(pos, iv);
            }
        }
    }

    /// Remove one instance of an obstacle previously added; `false`
    /// when the rectangle is not in the index. O(affected strips).
    pub fn remove(&mut self, r: &Rect) -> bool {
        let Some(pos) = self.obstacles.iter().position(|o| o == r) else {
            return false;
        };
        self.obstacles.swap_remove(pos);
        // The boundaries at r.y0 / r.y1() still exist (strips never
        // re-merge), so the splits below are defensive no-ops.
        self.split_at(r.y0);
        self.split_at(r.y1());
        let iv = (r.x0, r.x1());
        for s in self.strips.iter_mut() {
            if s.y0 >= r.y0 && s.y1 <= r.y1() {
                let p = s.xs.partition_point(|&e| e < iv);
                debug_assert!(s.xs.get(p) == Some(&iv), "indexed obstacle missing its interval");
                if s.xs.get(p) == Some(&iv) {
                    s.xs.remove(p);
                }
            }
        }
        true
    }

    /// Whether `r` intersects no indexed obstacle: walk only the
    /// strips `r` spans, and within each only the intervals starting
    /// left of `r`'s right edge.
    fn is_clear(&self, r: &Rect) -> bool {
        for s in &self.strips {
            if s.y1 <= r.y0 {
                continue;
            }
            if s.y0 >= r.y1() {
                break;
            }
            for &(x0, x1) in &s.xs {
                if x0 >= r.x1() {
                    break;
                }
                if x1 > r.x0 {
                    return false;
                }
            }
        }
        true
    }

    /// Bit-identical to [`place`] over [`Self::obstacles`]: same
    /// boundary-grid candidate set and `(y, x)` order, with each
    /// candidate's clearance answered by the strip walk instead of a
    /// full obstacle scan.
    pub fn place(&self, w: usize, h: usize) -> Option<Rect> {
        if w == 0 || h == 0 || w > self.nx || h > self.ny {
            return None;
        }
        let mut xs: Vec<usize> = vec![0, even_down(self.nx - w)];
        let mut ys: Vec<usize> = vec![0, even_down(self.ny - h)];
        for ob in &self.obstacles {
            xs.push(even_up(ob.x1()));
            xs.push(even_down(ob.x0.saturating_sub(w)));
            ys.push(even_up(ob.y1()));
            ys.push(even_down(ob.y0.saturating_sub(h)));
        }
        xs.retain(|&x| x + w <= self.nx);
        ys.retain(|&y| y + h <= self.ny);
        xs.sort_unstable();
        xs.dedup();
        ys.sort_unstable();
        ys.dedup();
        for &y in &ys {
            for &x in &xs {
                let r = Rect::new(x, y, w, h);
                if self.is_clear(&r) {
                    return Some(r);
                }
            }
        }
        None
    }

    /// Bit-identical to [`place_oriented`] over [`Self::obstacles`].
    pub fn place_oriented(&self, w: usize, h: usize) -> Option<Rect> {
        let a = self.place(w, h);
        if w == h {
            return a;
        }
        let b = self.place(h, w);
        match (a, b) {
            (Some(ra), Some(rb)) => {
                if (rb.y0, rb.x0) < (ra.y0, ra.x0) {
                    Some(rb)
                } else {
                    Some(ra)
                }
            }
            (a, b) => a.or(b),
        }
    }

    /// Bit-identical to [`largest_clear_rect`] over
    /// [`Self::obstacles`].
    pub fn largest_clear_rect(&self) -> (usize, usize, usize, usize) {
        largest_clear_rect(self.nx, self.ny, &self.obstacles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop;

    /// Brute-force bottom-left over even positions — the oracle for
    /// [`place`]'s boundary-grid candidate set.
    fn place_brute(nx: usize, ny: usize, obstacles: &[Rect], w: usize, h: usize) -> Option<Rect> {
        if w == 0 || h == 0 || w > nx || h > ny {
            return None;
        }
        for y in (0..=ny - h).step_by(2) {
            for x in (0..=nx - w).step_by(2) {
                let r = Rect::new(x, y, w, h);
                if obstacles.iter().all(|ob| !ob.overlaps(&r)) {
                    return Some(r);
                }
            }
        }
        None
    }

    fn random_obstacles(rng: &mut crate::util::rng::SplitMix64, nx: usize, ny: usize) -> Vec<Rect> {
        let mut obs: Vec<Rect> = Vec::new();
        for _ in 0..rng.usize_in(0, 6) {
            let w = 2 * rng.usize_in(1, 4);
            let h = 2 * rng.usize_in(1, 4);
            if w > nx || h > ny {
                continue;
            }
            let x0 = even_down(rng.usize_in(0, nx - w + 1));
            let y0 = even_down(rng.usize_in(0, ny - h + 1));
            let r = Rect::new(x0, y0, w, h);
            if obs.iter().all(|o| !o.overlaps(&r)) {
                obs.push(r);
            }
        }
        obs
    }

    #[test]
    fn prop_place_matches_brute_force_bottom_left() {
        prop("place == brute-force", |rng| {
            let nx = 2 * rng.usize_in(2, 10);
            let ny = 2 * rng.usize_in(2, 10);
            let obs = random_obstacles(rng, nx, ny);
            let w = 2 * rng.usize_in(1, 5);
            let h = 2 * rng.usize_in(1, 5);
            let got = place(nx, ny, &obs, w, h);
            let want = place_brute(nx, ny, &obs, w, h);
            assert_eq!(got, want, "{nx}x{ny} place {w}x{h} among {obs:?}");
            if let Some(r) = got {
                assert!(r.x0 % 2 == 0 && r.y0 % 2 == 0, "even-aligned: {r:?}");
                assert!(r.x1() <= nx && r.y1() <= ny);
                for ob in &obs {
                    assert!(!ob.overlaps(&r));
                }
            }
        });
    }

    #[test]
    fn place_prefers_bottom_left_and_respects_obstacles() {
        // A 2x2 obstacle at the origin pushes the placement right.
        let obs = [Rect::new(0, 0, 2, 2)];
        assert_eq!(place(8, 8, &obs, 4, 4), Some(Rect::new(2, 0, 4, 4)));
        // Full bottom strip occupied: next row band up.
        let strip = [Rect::new(0, 0, 8, 4)];
        assert_eq!(place(8, 8, &strip, 4, 4), Some(Rect::new(0, 4, 4, 4)));
        // No room at all.
        assert_eq!(place(4, 4, &[Rect::new(0, 0, 4, 4)], 2, 2), None);
        assert_eq!(place(4, 4, &[], 6, 2), None);
    }

    #[test]
    fn place_oriented_rotates_when_needed() {
        // Only a 2-wide, 6-tall column is free: a 6x2 request must
        // rotate.
        let obs = [Rect::new(2, 0, 6, 8)];
        let r = place_oriented(8, 8, &obs, 6, 2).unwrap();
        assert_eq!((r.w, r.h), (2, 6));
        assert_eq!((r.x0, r.y0), (0, 0));
        // Square requests skip the rotation.
        assert_eq!(place_oriented(8, 8, &[], 4, 4), place(8, 8, &[], 4, 4));
    }

    #[test]
    fn largest_clear_rect_counts_job_obstacles_too() {
        // One failed board + one placed job: the clear rect avoids
        // both (the generalisation largest_submesh cannot express).
        let obs = [Rect::new(0, 0, 2, 2), Rect::new(4, 0, 4, 8)];
        let (x0, y0, w, h) = largest_clear_rect(8, 8, &obs);
        assert_eq!((x0, y0, w, h), (0, 2, 4, 6));
        assert_eq!(largest_clear_rect_scan(8, 8, &obs), (x0, y0, w, h));
    }

    #[test]
    fn prop_prefix_sum_clear_rect_matches_scan() {
        // The O(1)-clearance implementation must reproduce the dense
        // per-candidate scan bit-for-bit, including on *overlapping*
        // obstacles (failed regions can sit inside job rectangles).
        prop("largest_clear_rect == scan", |rng| {
            let nx = rng.usize_in(1, 12);
            let ny = rng.usize_in(1, 12);
            let mut obs: Vec<Rect> = Vec::new();
            for _ in 0..rng.usize_in(0, 6) {
                let w = rng.usize_in(1, 5).min(nx);
                let h = rng.usize_in(1, 5).min(ny);
                let x0 = rng.usize_in(0, nx - w + 1);
                let y0 = rng.usize_in(0, ny - h + 1);
                obs.push(Rect::new(x0, y0, w, h)); // overlaps allowed
            }
            assert_eq!(
                largest_clear_rect(nx, ny, &obs),
                largest_clear_rect_scan(nx, ny, &obs),
                "{nx}x{ny} among {obs:?}"
            );
        });
    }

    #[test]
    fn prop_placement_index_tracks_the_scan_under_churn() {
        // Random add/remove sequences (duplicates and overlaps
        // allowed): after every update the index answers place /
        // place_oriented / largest_clear_rect exactly like the dense
        // scan over the same obstacle multiset.
        prop("placement index == dense scan", |rng| {
            let nx = 2 * rng.usize_in(2, 8);
            let ny = 2 * rng.usize_in(2, 8);
            let mut idx = PlacementIndex::new(nx, ny);
            let mut obs: Vec<Rect> = Vec::new();
            for _ in 0..rng.usize_in(2, 12) {
                if obs.is_empty() || rng.usize_in(0, 3) > 0 {
                    let w = (2 * rng.usize_in(1, 4)).min(nx);
                    let h = (2 * rng.usize_in(1, 4)).min(ny);
                    let x0 = even_down(rng.usize_in(0, nx - w + 1));
                    let y0 = even_down(rng.usize_in(0, ny - h + 1));
                    let r = Rect::new(x0, y0, w, h);
                    idx.add(&r);
                    obs.push(r);
                } else {
                    let r = obs.remove(rng.usize_in(0, obs.len()));
                    assert!(idx.remove(&r), "indexed obstacle must be removable");
                }
                let w = 2 * rng.usize_in(1, 4);
                let h = 2 * rng.usize_in(1, 4);
                assert_eq!(idx.place(w, h), place(nx, ny, &obs, w, h));
                assert_eq!(idx.place_oriented(w, h), place_oriented(nx, ny, &obs, w, h));
                assert_eq!(idx.largest_clear_rect(), largest_clear_rect_scan(nx, ny, &obs));
            }
            let whole = Rect::new(0, 0, nx, ny);
            assert!(!idx.remove(&whole) || obs.contains(&whole));
        });
    }

    #[test]
    fn even_shrink_rounds_inward() {
        assert_eq!(even_shrink(&Rect::new(1, 1, 5, 5)), Some(Rect::new(2, 2, 4, 4)));
        assert_eq!(even_shrink(&Rect::new(0, 0, 4, 4)), Some(Rect::new(0, 0, 4, 4)));
        assert_eq!(even_shrink(&Rect::new(1, 0, 2, 4)), None); // 1 col left
        assert_eq!(even_shrink(&Rect::new(0, 0, 1, 1)), None);
    }

    #[test]
    fn intersect_and_translate_roundtrip() {
        let rect = Rect::new(4, 2, 8, 6);
        let region = Rect::new(2, 4, 4, 4);
        let cut = intersect(&rect, &region).unwrap();
        assert_eq!(cut, Rect::new(4, 4, 2, 2));
        let local = to_local(&rect, &cut);
        assert_eq!(local, Rect::new(0, 2, 2, 2));
        assert_eq!(to_cluster(&rect, &local), cut);
        assert_eq!(intersect(&rect, &Rect::new(0, 0, 2, 2)), None);
    }

    #[test]
    fn check_rects_flags_violations() {
        assert!(check_rects(8, 8, &[Rect::new(0, 0, 4, 4), Rect::new(4, 4, 4, 4)]).is_ok());
        assert!(matches!(
            check_rects(8, 8, &[Rect::new(6, 6, 4, 2)]),
            Err(PlacementViolation::OutOfBounds(..))
        ));
        assert!(matches!(
            check_rects(8, 8, &[Rect::new(0, 0, 4, 4), Rect::new(2, 2, 4, 4)]),
            Err(PlacementViolation::Overlap(..))
        ));
    }
}
