//! 2-D rectangle placement over the obstacle boundary grid.
//!
//! Obstacles are failed regions *and* already-placed job rectangles —
//! both are axis-aligned rectangles, so [`FailedRegion`]'s geometry is
//! reused as the [`Rect`] type. Two primitives:
//!
//! - [`place`] — bottom-left placement of a `w x h` rectangle. The
//!   candidate corner set is drawn from the obstacle boundary grid
//!   (mesh edges, obstacle right/top edges, obstacle left/bottom edges
//!   minus the rectangle size) snapped to even coordinates, which is
//!   *complete* for even placements: pushing any valid placement down
//!   then left (in steps of two) stops on a boundary-grid candidate.
//!   Even snapping keeps every future in-rectangle failed region
//!   even-aligned in the job's local coordinates — the fault-tolerant
//!   planner's precondition (paper Fig 8).
//! - [`largest_clear_rect`] — exact maximum-empty-rectangle over the
//!   boundary grid (every maximal empty rectangle has its edges on
//!   obstacle boundaries or the mesh edge). `largest_submesh` in
//!   `coordinator::policy` is the failed-regions-only special case and
//!   delegates here.

use crate::mesh::FailedRegion;
use thiserror::Error;

/// Axis-aligned rectangle on the cluster mesh (`x0`, `y0`, `w`, `h`).
pub type Rect = FailedRegion;

/// A violated placement invariant (see the module docs of
/// [`crate::sched`]).
#[derive(Debug, Error, PartialEq, Eq)]
pub enum PlacementViolation {
    #[error("rectangle {0:?} leaves the {1}x{2} mesh")]
    OutOfBounds(Rect, usize, usize),
    #[error("rectangles {0:?} and {1:?} overlap")]
    Overlap(Rect, Rect),
}

/// Bounds + pairwise-disjointness check over a set of placed
/// rectangles.
pub fn check_rects(nx: usize, ny: usize, rects: &[Rect]) -> Result<(), PlacementViolation> {
    for (i, r) in rects.iter().enumerate() {
        if r.x1() > nx || r.y1() > ny {
            return Err(PlacementViolation::OutOfBounds(*r, nx, ny));
        }
        if let Some(other) = rects[i + 1..].iter().find(|o| o.overlaps(r)) {
            return Err(PlacementViolation::Overlap(*r, *other));
        }
    }
    Ok(())
}

/// Intersection of two rectangles, if non-empty.
pub fn intersect(a: &Rect, b: &Rect) -> Option<Rect> {
    let x0 = a.x0.max(b.x0);
    let y0 = a.y0.max(b.y0);
    let x1 = a.x1().min(b.x1());
    let y1 = a.y1().min(b.y1());
    if x0 < x1 && y0 < y1 {
        Some(Rect::new(x0, y0, x1 - x0, y1 - y0))
    } else {
        None
    }
}

/// Translate `r` (cluster coords, fully inside `rect`) into `rect`'s
/// local coordinates.
pub fn to_local(rect: &Rect, r: &Rect) -> Rect {
    debug_assert!(r.x0 >= rect.x0 && r.y0 >= rect.y0 && r.x1() <= rect.x1() && r.y1() <= rect.y1());
    Rect::new(r.x0 - rect.x0, r.y0 - rect.y0, r.w, r.h)
}

/// Translate `r` from `rect`'s local coordinates back to cluster
/// coordinates.
pub fn to_cluster(rect: &Rect, r: &Rect) -> Rect {
    Rect::new(rect.x0 + r.x0, rect.y0 + r.y0, r.w, r.h)
}

fn even_up(v: usize) -> usize {
    v + (v & 1)
}

fn even_down(v: usize) -> usize {
    v & !1usize
}

/// Bottom-left placement of a `w x h` rectangle avoiding every
/// obstacle, restricted to even-aligned positions. Returns the
/// placement with minimal `(y0, x0)`, or `None` when no even-aligned
/// position fits.
pub fn place(nx: usize, ny: usize, obstacles: &[Rect], w: usize, h: usize) -> Option<Rect> {
    if w == 0 || h == 0 || w > nx || h > ny {
        return None;
    }
    let mut xs: Vec<usize> = vec![0, even_down(nx - w)];
    let mut ys: Vec<usize> = vec![0, even_down(ny - h)];
    for ob in obstacles {
        xs.push(even_up(ob.x1()));
        xs.push(even_down(ob.x0.saturating_sub(w)));
        ys.push(even_up(ob.y1()));
        ys.push(even_down(ob.y0.saturating_sub(h)));
    }
    xs.retain(|&x| x + w <= nx);
    ys.retain(|&y| y + h <= ny);
    xs.sort_unstable();
    xs.dedup();
    ys.sort_unstable();
    ys.dedup();
    for &y in &ys {
        for &x in &xs {
            let r = Rect::new(x, y, w, h);
            if obstacles.iter().all(|ob| !ob.overlaps(&r)) {
                return Some(r);
            }
        }
    }
    None
}

/// [`place`] trying both orientations (`w x h` first, then rotated);
/// when both fit, the lower `(y0, x0)` corner wins, ties preferring
/// the requested orientation.
pub fn place_oriented(
    nx: usize,
    ny: usize,
    obstacles: &[Rect],
    w: usize,
    h: usize,
) -> Option<Rect> {
    let a = place(nx, ny, obstacles, w, h);
    if w == h {
        return a;
    }
    let b = place(nx, ny, obstacles, h, w);
    match (a, b) {
        (Some(ra), Some(rb)) => {
            if (rb.y0, rb.x0) < (ra.y0, ra.x0) {
                Some(rb)
            } else {
                Some(ra)
            }
        }
        (a, b) => a.or(b),
    }
}

/// Largest axis-aligned clear rectangle of `nx x ny` avoiding **all**
/// `obstacles`, as `(x0, y0, w, h)`. Ties prefer more chips, then
/// wider shapes. With no obstacles the answer is the full mesh.
///
/// The candidate edges are drawn from the obstacle boundary grid
/// (every maximal empty rectangle has its edges on obstacle boundaries
/// or the mesh edge), so the result is exact for any number of
/// disjoint rectangular obstacles.
pub fn largest_clear_rect(
    nx: usize,
    ny: usize,
    obstacles: &[Rect],
) -> (usize, usize, usize, usize) {
    let mut xs = vec![0, nx];
    let mut ys = vec![0, ny];
    for r in obstacles {
        xs.push(r.x0.min(nx));
        xs.push(r.x1().min(nx));
        ys.push(r.y0.min(ny));
        ys.push(r.y1().min(ny));
    }
    xs.sort_unstable();
    xs.dedup();
    ys.sort_unstable();
    ys.dedup();

    let clear = |x0: usize, y0: usize, x1: usize, y1: usize| {
        let candidate = Rect::new(x0, y0, x1 - x0, y1 - y0);
        obstacles.iter().all(|r| !r.overlaps(&candidate))
    };

    let mut best = (0, 0, 0, 0);
    let mut best_key = (0usize, 0usize);
    for (i, &x0) in xs.iter().enumerate() {
        for &x1 in &xs[i + 1..] {
            for (j, &y0) in ys.iter().enumerate() {
                for &y1 in &ys[j + 1..] {
                    if !clear(x0, y0, x1, y1) {
                        continue;
                    }
                    let (w, h) = (x1 - x0, y1 - y0);
                    let key = (w * h, w);
                    if key > best_key {
                        best_key = key;
                        best = (x0, y0, w, h);
                    }
                }
            }
        }
    }
    best
}

/// Largest *even-aligned, even-sized* sub-rectangle of a local clear
/// rectangle: origin rounded up to even, dims rounded down. `None`
/// when fewer than 2x2 chips remain — the smallest schedulable
/// sub-mesh.
pub fn even_shrink(r: &Rect) -> Option<Rect> {
    let x0 = even_up(r.x0);
    let y0 = even_up(r.y0);
    if x0 >= r.x1() || y0 >= r.y1() {
        return None;
    }
    let w = even_down(r.x1() - x0);
    let h = even_down(r.y1() - y0);
    if w < 2 || h < 2 {
        return None;
    }
    Some(Rect::new(x0, y0, w, h))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop;

    /// Brute-force bottom-left over even positions — the oracle for
    /// [`place`]'s boundary-grid candidate set.
    fn place_brute(nx: usize, ny: usize, obstacles: &[Rect], w: usize, h: usize) -> Option<Rect> {
        if w == 0 || h == 0 || w > nx || h > ny {
            return None;
        }
        for y in (0..=ny - h).step_by(2) {
            for x in (0..=nx - w).step_by(2) {
                let r = Rect::new(x, y, w, h);
                if obstacles.iter().all(|ob| !ob.overlaps(&r)) {
                    return Some(r);
                }
            }
        }
        None
    }

    fn random_obstacles(rng: &mut crate::util::rng::SplitMix64, nx: usize, ny: usize) -> Vec<Rect> {
        let mut obs: Vec<Rect> = Vec::new();
        for _ in 0..rng.usize_in(0, 6) {
            let w = 2 * rng.usize_in(1, 4);
            let h = 2 * rng.usize_in(1, 4);
            if w > nx || h > ny {
                continue;
            }
            let x0 = even_down(rng.usize_in(0, nx - w + 1));
            let y0 = even_down(rng.usize_in(0, ny - h + 1));
            let r = Rect::new(x0, y0, w, h);
            if obs.iter().all(|o| !o.overlaps(&r)) {
                obs.push(r);
            }
        }
        obs
    }

    #[test]
    fn prop_place_matches_brute_force_bottom_left() {
        prop("place == brute-force", |rng| {
            let nx = 2 * rng.usize_in(2, 10);
            let ny = 2 * rng.usize_in(2, 10);
            let obs = random_obstacles(rng, nx, ny);
            let w = 2 * rng.usize_in(1, 5);
            let h = 2 * rng.usize_in(1, 5);
            let got = place(nx, ny, &obs, w, h);
            let want = place_brute(nx, ny, &obs, w, h);
            assert_eq!(got, want, "{nx}x{ny} place {w}x{h} among {obs:?}");
            if let Some(r) = got {
                assert!(r.x0 % 2 == 0 && r.y0 % 2 == 0, "even-aligned: {r:?}");
                assert!(r.x1() <= nx && r.y1() <= ny);
                for ob in &obs {
                    assert!(!ob.overlaps(&r));
                }
            }
        });
    }

    #[test]
    fn place_prefers_bottom_left_and_respects_obstacles() {
        // A 2x2 obstacle at the origin pushes the placement right.
        let obs = [Rect::new(0, 0, 2, 2)];
        assert_eq!(place(8, 8, &obs, 4, 4), Some(Rect::new(2, 0, 4, 4)));
        // Full bottom strip occupied: next row band up.
        let strip = [Rect::new(0, 0, 8, 4)];
        assert_eq!(place(8, 8, &strip, 4, 4), Some(Rect::new(0, 4, 4, 4)));
        // No room at all.
        assert_eq!(place(4, 4, &[Rect::new(0, 0, 4, 4)], 2, 2), None);
        assert_eq!(place(4, 4, &[], 6, 2), None);
    }

    #[test]
    fn place_oriented_rotates_when_needed() {
        // Only a 2-wide, 6-tall column is free: a 6x2 request must
        // rotate.
        let obs = [Rect::new(2, 0, 6, 8)];
        let r = place_oriented(8, 8, &obs, 6, 2).unwrap();
        assert_eq!((r.w, r.h), (2, 6));
        assert_eq!((r.x0, r.y0), (0, 0));
        // Square requests skip the rotation.
        assert_eq!(place_oriented(8, 8, &[], 4, 4), place(8, 8, &[], 4, 4));
    }

    #[test]
    fn largest_clear_rect_counts_job_obstacles_too() {
        // One failed board + one placed job: the clear rect avoids
        // both (the generalisation largest_submesh cannot express).
        let obs = [Rect::new(0, 0, 2, 2), Rect::new(4, 0, 4, 8)];
        let (x0, y0, w, h) = largest_clear_rect(8, 8, &obs);
        assert_eq!((x0, y0, w, h), (0, 2, 4, 6));
    }

    #[test]
    fn even_shrink_rounds_inward() {
        assert_eq!(even_shrink(&Rect::new(1, 1, 5, 5)), Some(Rect::new(2, 2, 4, 4)));
        assert_eq!(even_shrink(&Rect::new(0, 0, 4, 4)), Some(Rect::new(0, 0, 4, 4)));
        assert_eq!(even_shrink(&Rect::new(1, 0, 2, 4)), None); // 1 col left
        assert_eq!(even_shrink(&Rect::new(0, 0, 1, 1)), None);
    }

    #[test]
    fn intersect_and_translate_roundtrip() {
        let rect = Rect::new(4, 2, 8, 6);
        let region = Rect::new(2, 4, 4, 4);
        let cut = intersect(&rect, &region).unwrap();
        assert_eq!(cut, Rect::new(4, 4, 2, 2));
        let local = to_local(&rect, &cut);
        assert_eq!(local, Rect::new(0, 2, 2, 2));
        assert_eq!(to_cluster(&rect, &local), cut);
        assert_eq!(intersect(&rect, &Rect::new(0, 0, 2, 2)), None);
    }

    #[test]
    fn check_rects_flags_violations() {
        assert!(check_rects(8, 8, &[Rect::new(0, 0, 4, 4), Rect::new(4, 4, 4, 4)]).is_ok());
        assert!(matches!(
            check_rects(8, 8, &[Rect::new(6, 6, 4, 2)]),
            Err(PlacementViolation::OutOfBounds(..))
        ));
        assert!(matches!(
            check_rects(8, 8, &[Rect::new(0, 0, 4, 4), Rect::new(2, 2, 4, 4)]),
            Err(PlacementViolation::Overlap(..))
        ));
    }
}
