//! The real-trainer fleet path: every placed job drives an actual
//! [`DataParallelTrainer`] on its sub-mesh, anchored at its physical
//! origin through `TrainerConfig::{x0, y0}` (so each chip keeps the
//! data shard of its physical position), with **one process-wide
//! [`SharedPlanCache`]** handed to every trainer — jobs with equal
//! sub-mesh shapes reuse each other's compiled allreduce plans, and a
//! migrated job warm-starts from the plans its previous placement
//! compiled.
//!
//! Placement moves preserve the replica **bit-identically**: a
//! migration/shrink checkpoints the live trainer, rebuilds it at the
//! new origin, and restores — checkpoint/restore is exact, and a
//! fault-tolerant rejoin re-broadcasts through the allreduce with a
//! built-in bit-identity check (`trainer::rejoin_region`). The
//! property tests in `rust/tests/fleet_placement.rs` assert the
//! fail→migrate→repair round-trip end to end.
//!
//! This engine favours correctness over scale (the simulated engine in
//! [`super::fleet`] is the throughput instrument): jobs step in
//! lockstep, and queue-wait is approximated by migrate. Wall-clock
//! asynchrony and cross-job link contention are likewise properties of
//! the simulated engine only ([`super::fleet::ClockMode::WallClock`] +
//! [`super::contention`]) — real trainers here share one process, so
//! their wall time would measure the host, not the modelled fabric.

use super::placer::{self, Rect};
use super::{FleetError, JobPolicy, JobSpec};
use crate::cluster::{ClusterEvent, ClusterState};
use crate::collective::{PlanCacheStats, SharedPlanCache};
use crate::mesh::{FailedRegion, Topology};
use crate::perfmodel::predict_candidate_shared;
use crate::runtime::Runtime;
use crate::simnet::LinkModel;
use crate::trainer::metrics::StepRecord;
use crate::trainer::{DataParallelTrainer, TrainError, TrainerConfig};

/// One placed job running a real trainer on its rectangle.
pub struct TrainedJob {
    pub spec: JobSpec,
    pub rect: Rect,
    pub trainer: DataParallelTrainer,
    model: String,
    seed: u64,
    cache: SharedPlanCache,
}

impl TrainedJob {
    /// Build and place a trainer for `spec` on `rect`, sharing
    /// `cache`.
    pub fn launch(
        model: &str,
        spec: JobSpec,
        rect: Rect,
        cache: SharedPlanCache,
    ) -> Result<Self, FleetError> {
        let seed = 1000 + spec.id as u64;
        let trainer = build_trainer(model, seed, rect, Vec::new(), &cache)?;
        Ok(Self { spec, rect, trainer, model: model.to_string(), seed, cache })
    }

    /// One training step on the job's sub-mesh.
    pub fn step(&mut self) -> Result<StepRecord, FleetError> {
        Ok(self.trainer.train_step()?)
    }

    /// Local failed regions, in cluster coordinates.
    pub fn holes(&self) -> Vec<Rect> {
        self.trainer
            .topology()
            .failed_regions()
            .iter()
            .map(|r| placer::to_cluster(&self.rect, r))
            .collect()
    }

    /// Continue fault-tolerant: inject the in-rectangle cut into the
    /// live trainer (the paper's scheme on the job's sub-mesh).
    pub fn continue_ft(&mut self, cut: Rect) -> Result<(), FleetError> {
        let local = placer::to_local(&self.rect, &cut);
        self.trainer.inject_failure(local)?;
        Ok(())
    }

    /// Rejoin a repaired in-rectangle cut (replica re-broadcast with
    /// the built-in bit-identity check).
    pub fn rejoin(&mut self, cut: Rect) -> Result<(), FleetError> {
        let local = placer::to_local(&self.rect, &cut);
        self.trainer.rejoin_region(local)?;
        Ok(())
    }

    /// Move to `target` (migration or shrink): checkpoint the live
    /// trainer, rebuild at the new origin with the shared cache, and
    /// restore — the replica crosses the move bit-identically.
    pub fn move_to(&mut self, target: Rect) -> Result<(), FleetError> {
        let ck = self.trainer.checkpoint();
        let mut trainer = build_trainer(&self.model, self.seed, target, Vec::new(), &self.cache)?;
        std::mem::swap(&mut trainer.metrics, &mut self.trainer.metrics);
        trainer.restore(ck);
        trainer.metrics.annotate(
            trainer.step,
            format!(
                "job {} moved to {}x{} at ({},{})",
                self.spec.id, target.w, target.h, target.x0, target.y0
            ),
        );
        self.trainer = trainer;
        self.rect = target;
        Ok(())
    }

    /// Mean measured per-worker compute over recent steps (the
    /// adaptive comparison's compute half); nominal before any step.
    fn measured_compute_s(&self) -> f64 {
        let records = &self.trainer.metrics.records;
        let tail = &records[records.len().saturating_sub(5)..];
        if tail.is_empty() {
            return 0.01;
        }
        let sum: f64 = tail.iter().map(|r| r.compute_s / r.workers.max(1) as f64).sum();
        sum / tail.len() as f64
    }
}

fn build_trainer(
    model: &str,
    seed: u64,
    rect: Rect,
    failed: Vec<FailedRegion>,
    cache: &SharedPlanCache,
) -> Result<DataParallelTrainer, FleetError> {
    let mut tcfg = TrainerConfig::new(model, rect.w, rect.h);
    tcfg.x0 = rect.x0;
    tcfg.y0 = rect.y0;
    tcfg.seed = seed;
    tcfg.failed = failed;
    let runtime = Runtime::cpu().map_err(TrainError::Runtime)?;
    Ok(DataParallelTrainer::new_with_cache(tcfg, &runtime, cache.clone())?)
}

/// Configuration of the real-trainer fleet.
#[derive(Debug, Clone)]
pub struct TrainedFleetConfig {
    /// Model config name ("tiny", ...); needs compiled artifacts.
    pub model: String,
    pub nx: usize,
    pub ny: usize,
}

/// A small multi-job fleet of real trainers on one cluster mesh,
/// driven by explicit launches, steps and events (tests and examples
/// script it; the simulated engine handles workload-scale runs).
pub struct TrainedFleet {
    pub cfg: TrainedFleetConfig,
    pub cluster: ClusterState,
    pub jobs: Vec<TrainedJob>,
    cache: SharedPlanCache,
}

impl TrainedFleet {
    pub fn new(cfg: TrainedFleetConfig) -> Self {
        let cluster = ClusterState::new(cfg.nx, cfg.ny);
        Self { cfg, cluster, jobs: Vec::new(), cache: SharedPlanCache::new(64) }
    }

    /// Counters of the process-wide cache all jobs share.
    pub fn cache_stats(&self) -> PlanCacheStats {
        self.cache.stats()
    }

    fn obstacles_excluding(&self, skip: usize) -> Vec<Rect> {
        let mut obs: Vec<Rect> = self.cluster.failed_regions().to_vec();
        for (i, j) in self.jobs.iter().enumerate() {
            if i != skip {
                obs.push(j.rect);
            }
        }
        obs
    }

    /// Place and launch a job; returns its index.
    pub fn launch(&mut self, spec: JobSpec) -> Result<usize, FleetError> {
        let obs = self.obstacles_excluding(usize::MAX);
        let Some(rect) = placer::place_oriented(self.cfg.nx, self.cfg.ny, &obs, spec.w, spec.h)
        else {
            return Err(FleetError::Unplaceable(spec.id, spec.w, spec.h));
        };
        let job = TrainedJob::launch(&self.cfg.model, spec, rect, self.cache.clone())?;
        self.jobs.push(job);
        self.check_invariants()?;
        Ok(self.jobs.len() - 1)
    }

    /// One lockstep training step across every job.
    pub fn step_all(&mut self) -> Result<(), FleetError> {
        for job in &mut self.jobs {
            job.step()?;
        }
        Ok(())
    }

    fn migrate_job(&mut self, i: usize, cut: Rect) -> Result<(), FleetError> {
        let (w, h) = (self.jobs[i].spec.w, self.jobs[i].spec.h);
        let obs = self.obstacles_excluding(i);
        let Some(target) = placer::place_oriented(self.cfg.nx, self.cfg.ny, &obs, w, h) else {
            return self.shrink_job(i, cut);
        };
        self.jobs[i].move_to(target)
    }

    /// Shrink job `i` within its rectangle, avoiding its existing
    /// holes *and* the freshly failed `cut` (which has not been
    /// injected into the trainer yet — only `continue_ft` does that).
    fn shrink_job(&mut self, i: usize, cut: Rect) -> Result<(), FleetError> {
        let rect = self.jobs[i].rect;
        let mut local: Vec<Rect> = self.jobs[i].trainer.topology().failed_regions().to_vec();
        let local_cut = placer::to_local(&rect, &cut);
        if !local.contains(&local_cut) {
            local.push(local_cut);
        }
        let (lx, ly, lw, lh) = placer::largest_clear_rect(rect.w, rect.h, &local);
        let sub = (lw * lh > 0)
            .then(|| placer::even_shrink(&Rect::new(lx, ly, lw, lh)))
            .flatten();
        let Some(sub) = sub else {
            return Err(FleetError::Unschedulable(self.jobs[i].spec.id, rect.w, rect.h));
        };
        let target = placer::to_cluster(&rect, &sub);
        self.jobs[i].move_to(target)
    }

    /// Adaptive arbitration with *measured* compute: continue-FT on
    /// the degraded sub-mesh vs migrate to a fresh rectangle, by
    /// predicted training throughput through the shared cache.
    fn adaptive_job(&mut self, i: usize, cut: Rect) -> Result<(), FleetError> {
        let link = LinkModel::tpu_v3();
        let job = &self.jobs[i];
        let compute = job.measured_compute_s();
        let payload = job.trainer.param_count();
        let local_cut = placer::to_local(&job.rect, &cut);
        let mut regions = job.trainer.topology().failed_regions().to_vec();
        regions.push(local_cut);
        let ft_topo = Topology::with_failures(job.rect.w, job.rect.h, regions);
        let ft = if ft_topo.is_connected() {
            predict_candidate_shared(&ft_topo, payload, &link, compute, &self.cache).ok()
        } else {
            None
        };
        let obs = self.obstacles_excluding(i);
        let target =
            placer::place_oriented(self.cfg.nx, self.cfg.ny, &obs, job.spec.w, job.spec.h);
        let mig = target.and_then(|t| {
            predict_candidate_shared(&Topology::full(t.w, t.h), payload, &link, compute, &self.cache)
                .ok()
                .map(|p| (t, p))
        });
        match (ft, mig) {
            (Some(f), Some((t, m))) => {
                if f.throughput >= m.throughput {
                    self.jobs[i].continue_ft(cut)
                } else {
                    self.jobs[i].move_to(t)
                }
            }
            (Some(_), None) => self.jobs[i].continue_ft(cut),
            (None, Some((t, _))) => self.jobs[i].move_to(t),
            (None, None) => self.shrink_job(i, cut),
        }
    }

    /// Apply one cluster event, routing consequences to each affected
    /// job's policy.
    pub fn handle(&mut self, event: ClusterEvent) -> Result<(), FleetError> {
        match event {
            ClusterEvent::Fail(region) => {
                self.cluster.fail(region)?;
                for i in 0..self.jobs.len() {
                    let rect = self.jobs[i].rect;
                    let Some(cut) = placer::intersect(&rect, &region) else { continue };
                    match self.jobs[i].spec.policy {
                        // The trained fleet provisions no spares, so a
                        // reconfigure vote degrades to continue-FT —
                        // the same fallback the simulated engine takes
                        // with the spare budget exhausted.
                        JobPolicy::Continue | JobPolicy::Reconfigure => {
                            self.jobs[i].continue_ft(cut)?
                        }
                        JobPolicy::Shrink => self.shrink_job(i, cut)?,
                        // Queue-wait has no meaning for a lockstep
                        // trained fleet; approximate with migrate.
                        JobPolicy::Migrate | JobPolicy::Wait => self.migrate_job(i, cut)?,
                        JobPolicy::Adaptive => self.adaptive_job(i, cut)?,
                    }
                }
            }
            ClusterEvent::Repair(region) => {
                self.cluster.repair(region)?;
                for i in 0..self.jobs.len() {
                    let rect = self.jobs[i].rect;
                    let Some(cut) = placer::intersect(&rect, &region) else { continue };
                    let local = placer::to_local(&rect, &cut);
                    let has_hole =
                        self.jobs[i].trainer.topology().failed_regions().contains(&local);
                    if has_hole {
                        self.jobs[i].rejoin(cut)?;
                    }
                }
            }
            // No spares here: a forced reconfigure has nothing to heal.
            ClusterEvent::Reconfig | ClusterEvent::CheckpointTick | ClusterEvent::Stop => {}
        }
        self.check_invariants()
    }

    /// The placement invariants over live trainers.
    pub fn check_invariants(&self) -> Result<(), FleetError> {
        let fail = |violation: String| FleetError::Invariant { step: 0, violation };
        let rects: Vec<Rect> = self.jobs.iter().map(|j| j.rect).collect();
        placer::check_rects(self.cfg.nx, self.cfg.ny, &rects).map_err(|e| fail(e.to_string()))?;
        for f in self.cluster.failed_regions() {
            for j in &self.jobs {
                if let Some(cut) = placer::intersect(&j.rect, f) {
                    if !j.holes().contains(&cut) {
                        return Err(fail(format!(
                            "job {} overlaps failed {f:?} without training around it",
                            j.spec.id
                        )));
                    }
                }
            }
        }
        for j in &self.jobs {
            for h in j.holes() {
                let backed = self
                    .cluster
                    .failed_regions()
                    .iter()
                    .any(|f| placer::intersect(f, &h) == Some(h));
                if !backed {
                    return Err(fail(format!(
                        "job {} trains around {h:?} which is not a live failure",
                        j.spec.id
                    )));
                }
            }
        }
        Ok(())
    }
}
