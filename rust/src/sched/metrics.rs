//! Fleet metrics: utilization, job completion time, goodput,
//! migration counts, contention dilation / link hotspots — and the
//! `BENCH_fleet.json` rows.

use super::{JobClass, JobPolicy};
use crate::collective::PlanCacheStats;
use crate::obs::Registry;
use crate::util::bench::JsonReport;

/// One sampled point of the fleet's utilization/goodput curve.
#[derive(Debug, Clone, Copy)]
pub struct UtilSample {
    pub step: u64,
    /// Fraction of *live* chips allocated to running jobs at this
    /// step.
    pub utilization: f64,
    /// Worker-steps of training progress delivered at this step.
    pub goodput: f64,
    pub running: usize,
    pub queued: usize,
    /// Largest cross-job contention dilation among running jobs at
    /// this step (1.0 = uncontended; the contention-dilation curve).
    pub max_dilation: f64,
}

/// Per-job outcome of one fleet run.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub id: usize,
    pub w: usize,
    pub h: usize,
    pub policy: JobPolicy,
    pub class: JobClass,
    pub arrival_step: u64,
    /// Fleet step the job finished its work, `None` if the horizon
    /// ended first (the normal outcome for serving jobs).
    pub completed_at: Option<u64>,
    pub migrations: u64,
    pub shrinks: u64,
    pub ft_continues: u64,
    /// Fleet steps spent in the queue (arrival wait + queue-wait
    /// evictions).
    pub waited_steps: u64,
    /// Offered requests over the job's lifetime (serving jobs; 0.0
    /// for training).
    pub requests: f64,
    /// Requests served within the job's SLO threshold.
    pub slo_met: f64,
}

impl JobOutcome {
    /// Job completion time: arrival to completion, in fleet steps.
    pub fn jct(&self) -> Option<u64> {
        self.completed_at.map(|c| c.saturating_sub(self.arrival_step))
    }
}

/// One hot cluster edge: time-averaged charged occupancy under the
/// contention accounting (the per-link-hotspot curve).
#[derive(Debug, Clone, Copy)]
pub struct LinkHotspot {
    pub x: usize,
    pub y: usize,
    /// `Dir::index()` of the directed edge leaving `(x, y)`.
    pub dir: usize,
    /// Charged occupancy integrated over the horizon, divided by the
    /// horizon — mean busy fraction of the edge.
    pub mean_occupancy: f64,
}

impl LinkHotspot {
    pub fn dir_name(&self) -> &'static str {
        ["east", "west", "north", "south"][self.dir.min(3)]
    }
}

/// Aggregate outcome of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetSummary {
    pub horizon: u64,
    pub arrivals: usize,
    pub completed: usize,
    /// Mean / median JCT over completed jobs (fleet steps; 0 when none
    /// completed).
    pub mean_jct: f64,
    pub median_jct: f64,
    /// Mean fraction of live chips allocated over the horizon.
    pub mean_utilization: f64,
    /// Mean worker-steps of training progress delivered per fleet
    /// step — the figure the migrate-vs-continue arbitration moves.
    pub goodput: f64,
    pub migrations: u64,
    pub shrinks: u64,
    pub ft_continues: u64,
    /// Recovery decisions that sent a job back to the queue.
    pub queue_waits: u64,
    /// Jobs admitted around a blocked FIFO head (`FleetConfig::backfill`).
    pub backfills: u64,
    /// Fail/repair events replayed.
    pub transitions: u64,
    /// Heals adopted (link-remap changes), each pausing every running
    /// job for `FleetConfig::rewire_steps`.
    pub rewires: u64,
    /// Job-time-weighted mean cross-job contention dilation (1.0 when
    /// contention is off or never binds).
    pub mean_dilation: f64,
    /// Largest dilation any job saw over the run.
    pub max_dilation: f64,
    /// Contention fair-share recomputations (link epochs).
    pub contention_epochs: u64,
    /// Simulation segments processed (round-robin steps or wall-clock
    /// integration segments) — the event count behind the engine's
    /// events/sec throughput metric (`BENCH_scale.json`).
    pub segments: u64,
    /// Per-run plan-cache counters: the shared cache's cumulative
    /// stats deltaed against a snapshot taken when the run started, so
    /// runs sharing one `SharedPlanCache` report only their own
    /// traffic.
    pub cache: PlanCacheStats,
    /// Fraction of offered serving requests answered within their SLO
    /// threshold (1.0 when the run has no serving traffic — a missing
    /// tier attains trivially).
    pub slo_attainment: f64,
    /// Request-weighted 99th-percentile serving latency,
    /// milliseconds (0.0 without serving traffic). Requests arriving
    /// while a serving job is queued or paused wait the outage out,
    /// so recovery time flows into this figure.
    pub serving_p99_ms: f64,
    /// Training placements evicted to make room for a serving
    /// rectangle (checkpoint, evict, re-place via the migrate path).
    pub preemptions: u64,
}

/// Per-phase wall-time breakdown of one fleet run (`bin/scale.rs
/// --profile`). Phases are measured around the engine's code paths
/// with `Instant` accumulators that never feed back into the
/// simulation, so profiling does not perturb determinism. Phases can
/// nest (recovery placement inside drain counts toward both
/// `placement_s` and `drain_s`); each figure answers "how much wall
/// time did this code path cost", not "do the figures sum to the
/// total".
#[derive(Debug, Clone, Copy, Default)]
pub struct FleetProfile {
    /// Placement queries: arrivals, backfill, migrate/recover targets,
    /// grow-back, and defrag trial placements.
    pub placement_s: f64,
    /// MTBF timeline generation — dominated by the failure-site picker.
    pub site_pick_s: f64,
    /// Contention fair-share recomputations (link epochs).
    pub contention_s: f64,
    /// Fail/repair event drains (includes recovery placement).
    pub drain_s: f64,
    /// Step execution: round-robin stepping or wall-clock segment
    /// integration.
    pub executor_s: f64,
}

/// One fleet run: summary + per-job outcomes + sampled curves + link
/// hotspots + the annotated event log.
#[derive(Debug, Clone)]
pub struct FleetRun {
    /// Policy label ("continue-ft", "migrate", ..., or "mixed").
    pub label: String,
    pub summary: FleetSummary,
    pub jobs: Vec<JobOutcome>,
    pub samples: Vec<UtilSample>,
    /// Top cluster edges by time-integrated charged occupancy (empty
    /// when contention accounting is off).
    pub hotspots: Vec<LinkHotspot>,
    pub events: Vec<(u64, String)>,
    /// Wall-time breakdown (excluded from run-equivalence checks).
    pub profile: FleetProfile,
    /// Typed metrics snapshot: recovery-latency histograms, DES and
    /// contention counters, hotspot-truncation counts, plan-cache
    /// counters, and the profile phases as gauges. Counters and
    /// histograms are deterministic; gauges hold wall-clock
    /// measurements and are excluded from run-equivalence checks.
    pub metrics: Registry,
}

/// Mean and median of a (small) sample.
pub(crate) fn mean_median(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    let mid = sorted.len() / 2;
    let median = if sorted.len() % 2 == 1 {
        sorted[mid]
    } else {
        0.5 * (sorted[mid - 1] + sorted[mid])
    };
    (mean, median)
}

/// Append one run's summary + curves to a `BENCH_fleet.json` report:
/// a `fleet_<label>` summary entry, one `fleet_<label>_t<step>` entry
/// per utilization/goodput/dilation sample, and one
/// `fleet_<label>_hot<i>` entry per link hotspot.
pub fn push_run(report: &mut JsonReport, run: &FleetRun) {
    let s = &run.summary;
    report.push(
        &format!("fleet_{}", run.label),
        if s.goodput > 0.0 { 1.0 / s.goodput } else { 0.0 },
        0.0,
        &[
            ("goodput", s.goodput),
            ("mean_utilization", s.mean_utilization),
            ("mean_jct", s.mean_jct),
            ("median_jct", s.median_jct),
            ("completed", s.completed as f64),
            ("arrivals", s.arrivals as f64),
            ("migrations", s.migrations as f64),
            ("shrinks", s.shrinks as f64),
            ("ft_continues", s.ft_continues as f64),
            ("queue_waits", s.queue_waits as f64),
            ("backfills", s.backfills as f64),
            ("transitions", s.transitions as f64),
            ("rewires", s.rewires as f64),
            ("mean_dilation", s.mean_dilation),
            ("max_dilation", s.max_dilation),
            ("contention_epochs", s.contention_epochs as f64),
            ("segments", s.segments as f64),
            ("cache_hit_rate", s.cache.hit_rate()),
            ("incremental_compiles", s.cache.incremental_compiles as f64),
            ("step_splice_rate", s.cache.step_splice_rate()),
            ("persist_loaded", s.cache.persist_loaded as f64),
            ("slo_attainment", s.slo_attainment),
            ("serving_p99_ms", s.serving_p99_ms),
            ("preemptions", s.preemptions as f64),
        ],
    );
    for p in &run.samples {
        report.push(
            &format!("fleet_{}_t{}", run.label, p.step),
            0.0,
            0.0,
            &[
                ("step", p.step as f64),
                ("utilization", p.utilization),
                ("goodput", p.goodput),
                ("running", p.running as f64),
                ("queued", p.queued as f64),
                ("max_dilation", p.max_dilation),
            ],
        );
    }
    for (i, h) in run.hotspots.iter().enumerate() {
        report.push(
            &format!("fleet_{}_hot{i}", run.label),
            0.0,
            0.0,
            &[
                ("x", h.x as f64),
                ("y", h.y as f64),
                ("dir", h.dir as f64),
                ("mean_occupancy", h.mean_occupancy),
            ],
        );
    }
    // The typed metrics snapshot: `fleet_<label>_metrics` plus one
    // `fleet_<label>_hist_<name>` entry per histogram (recovery
    // latencies, JCTs, DES makespans).
    run.metrics.push_to(report, &format!("fleet_{}", run.label));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jct_is_completion_minus_arrival() {
        let j = JobOutcome {
            id: 0,
            w: 4,
            h: 4,
            policy: JobPolicy::Adaptive,
            class: JobClass::Training,
            arrival_step: 10,
            completed_at: Some(250),
            migrations: 1,
            shrinks: 0,
            ft_continues: 2,
            waited_steps: 3,
            requests: 0.0,
            slo_met: 0.0,
        };
        assert_eq!(j.jct(), Some(240));
        let unfinished = JobOutcome { completed_at: None, ..j };
        assert_eq!(unfinished.jct(), None);
    }

    #[test]
    fn mean_median_handles_odd_even_empty() {
        assert_eq!(mean_median(&[]), (0.0, 0.0));
        let (m, md) = mean_median(&[1.0, 3.0, 2.0]);
        assert!((m - 2.0).abs() < 1e-12 && (md - 2.0).abs() < 1e-12);
        let (m, md) = mean_median(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12 && (md - 2.5).abs() < 1e-12);
    }

    #[test]
    fn hotspot_dir_names_are_total() {
        for (dir, name) in
            [(0, "east"), (1, "west"), (2, "north"), (3, "south"), (9, "south")]
        {
            let h = LinkHotspot { x: 1, y: 2, dir, mean_occupancy: 0.5 };
            assert_eq!(h.dir_name(), name);
        }
    }
}
