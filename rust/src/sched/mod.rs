//! Multi-tenant fleet scheduler: place, run, and heal many jobs on one
//! mesh.
//!
//! The paper keeps *one* training job alive by routing allreduce
//! traffic around holes; a production fleet runs **many concurrent
//! jobs on one mesh**, and every failure raises a *placement* question
//! — which jobs shrink, migrate, or continue fault-tolerant — not just
//! a routing one. This subsystem arbitrates the mesh between jobs:
//!
//! - [`workload`] — seeded arrival/size/duration job workloads
//!   (exponential inter-arrival and duration, shapes drawn from a
//!   board/host-aligned set; equal seeds give identical fleets);
//! - [`placer`] — the 2-D rectangle placer. Candidate corners come
//!   from the *obstacle boundary grid* (the same observation behind
//!   `largest_submesh`: every maximal empty rectangle has its edges on
//!   obstacle boundaries or the mesh edge), pushed bottom-left-first,
//!   and snapped to **even** coordinates so any failed region that
//!   later lands inside a job's rectangle stays even-aligned in the
//!   job's local coordinates — the fault-tolerant planner's
//!   precondition. [`placer::largest_clear_rect`] is the exact
//!   boundary-grid max-empty-rectangle over arbitrary obstacle sets
//!   (failed regions *and* placed jobs).
//!   [`placer::PlacementIndex`] maintains the obstacle set in
//!   horizontal strips across place/free/fail/repair so each query
//!   touches only affected strips instead of rescanning the mesh —
//!   gated by `FleetConfig::fast_placer`, bit-identical to the scans;
//! - [`fleet`] — the deterministic fleet engines. Both clock modes
//!   ([`fleet::ClockMode`]) consume the existing `cluster::EventQueue`
//!   and route each fail/repair to the affected job's [`JobPolicy`]:
//!   **continue-FT** in place (the paper's scheme on the job's
//!   sub-mesh), **shrink-restart** (the largest clear even
//!   sub-rectangle of its own allocation), **migrate** (a fresh
//!   rectangle elsewhere, paying restart + rollback), or
//!   **queue-wait**. [`JobPolicy::Adaptive`] arbitrates per event by
//!   predicted *effective throughput* over the expected
//!   time-to-next-event (the MTBF posterior), folding in each
//!   candidate's one-off costs — the Chameleon-style selection the
//!   coordinator applies to one job, generalised to a fleet. Repairs
//!   rejoin in-place holes, grow shrunk jobs back, and trigger
//!   **defragmenting re-placement** (bottom-left repack, largest
//!   first) when the queue head still does not fit. A FIFO-blocked
//!   head can optionally be **backfilled** around
//!   (`FleetConfig::backfill`). The wall-clock mode steps jobs
//!   asynchronously on a continuous timeline (one globally
//!   time-sorted event schedule, drained in same-instant batches)
//!   and, with contention enabled, dilates step times per link epoch;
//! - [`contention`] — cross-job link contention: each job's compiled
//!   plan charges per-edge occupancy (plus router-adjacency
//!   spillover), and edges shared by several jobs split their budget
//!   max-min fairly, dilating the sharers' allreduce terms;
//! - [`job`] — the real-trainer path: every placed job drives a
//!   `DataParallelTrainer` on its sub-mesh, anchored at its physical
//!   origin via `TrainerConfig::{x0, y0}`, all jobs sharing one
//!   process-wide `SharedPlanCache` so equal shapes reuse compiled
//!   plans; migrations checkpoint/restore the replica bit-identically;
//! - [`metrics`] — utilization / job-completion-time / goodput
//!   accounting and the `BENCH_fleet.json` rows.
//!
//! Placement invariants (checked every fleet step, and property-tested
//! in `rust/tests/fleet_placement.rs`): job rectangles fit the mesh
//! and are pairwise disjoint; every overlap between a live failed
//! region and a job rectangle is a registered hole of exactly that
//! job; new placements never overlap live failed regions.

pub mod contention;
pub mod fleet;
pub mod job;
pub mod metrics;
pub mod placer;
pub mod workload;

use crate::cluster::ClusterError;
use crate::collective::PlanError;
use crate::simnet::SimError;
use crate::trainer::TrainError;
use thiserror::Error;

pub use contention::{fair_shares, job_load, ContentionModel, EdgeCharge, JobLoad, ShareReport};
pub use fleet::{compare_policies, run_fleet, run_with_cache, ClockMode, FleetConfig};
pub use job::{TrainedFleet, TrainedFleetConfig, TrainedJob};
pub use metrics::{FleetProfile, FleetRun, FleetSummary, JobOutcome, LinkHotspot, UtilSample};
pub use placer::{
    largest_clear_rect, largest_clear_rect_scan, place, place_oriented, PlacementIndex, Rect,
};
pub use workload::{RequestProcess, ServingWorkload, WorkloadModel};

#[derive(Debug, Error)]
pub enum FleetError {
    #[error("plan: {0}")]
    Plan(#[from] PlanError),
    #[error("simulation: {0}")]
    Sim(#[from] SimError),
    #[error("cluster event rejected: {0}")]
    Cluster(#[from] ClusterError),
    #[error("train: {0}")]
    Train(#[from] TrainError),
    #[error("placement invariant violated at step {step}: {violation}")]
    Invariant { step: u64, violation: String },
    #[error("job {0}: {1}x{2} can never fit the mesh")]
    Unplaceable(usize, usize, usize),
    #[error("job {0}: hole-free {1}x{2} sub-mesh is not schedulable")]
    Unschedulable(usize, usize, usize),
}

/// Per-job recovery policy — what the fleet does to *this* job when a
/// failure intersects its rectangle (the fleet-level generalisation of
/// the coordinator's `RecoveryPolicy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobPolicy {
    /// Continue fault-tolerant in place: keep the rectangle, route the
    /// allreduce around the in-rectangle hole (the paper's scheme).
    Continue,
    /// Restart from checkpoint on the largest clear even sub-rectangle
    /// of the job's own allocation.
    Shrink,
    /// Restart from checkpoint on a freshly placed rectangle elsewhere
    /// on the mesh.
    Migrate,
    /// Release the rectangle and wait in the queue until placeable.
    Wait,
    /// Vote for reconfigurable-mesh healing: retire the failed chips'
    /// physical rows/columns onto the fleet's spare budget
    /// ([`crate::mesh::heal`]) so the job's logical rectangle stays
    /// hole-free; degrades to continue-FT when spares are exhausted or
    /// the fleet has none provisioned.
    Reconfigure,
    /// Pick among the above per event by predicted effective
    /// throughput over the expected time-to-next-event.
    Adaptive,
}

impl JobPolicy {
    pub const ALL: [JobPolicy; 6] = [
        JobPolicy::Continue,
        JobPolicy::Shrink,
        JobPolicy::Migrate,
        JobPolicy::Wait,
        JobPolicy::Reconfigure,
        JobPolicy::Adaptive,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            JobPolicy::Continue => "continue-ft",
            JobPolicy::Shrink => "shrink",
            JobPolicy::Migrate => "migrate",
            JobPolicy::Wait => "wait",
            JobPolicy::Reconfigure => "reconfigure",
            JobPolicy::Adaptive => "adaptive",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|p| p.name() == s)
    }
}

/// Workload class of a job: throughput-oriented training or
/// latency-sensitive serving (arXiv 2512.25059: one FT-collective /
/// plan-cache substrate shared by both classes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobClass {
    /// Batch training: progress measured in completed steps; goodput
    /// accounting, checkpoint/rollback recovery.
    Training,
    /// Online inference: runs until the horizon, serves a seeded
    /// request process, and is judged by a latency SLO instead of
    /// job-completion time.
    Serving,
}

impl JobClass {
    pub fn name(&self) -> &'static str {
        match self {
            JobClass::Training => "training",
            JobClass::Serving => "serving",
        }
    }
}

/// Per-job latency SLO for serving jobs: the request-latency
/// percentile that must land under `threshold_ms`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Target percentile in (0, 1], e.g. 0.99.
    pub percentile: f64,
    /// Latency threshold in milliseconds at that percentile.
    pub threshold_ms: f64,
}

/// One job of a fleet workload.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: usize,
    /// Fleet step at which the job enters the queue.
    pub arrival_step: u64,
    /// Requested sub-mesh shape (even dims; the placer may rotate).
    pub w: usize,
    pub h: usize,
    /// Training steps of work the job must complete. Serving jobs use
    /// `u64::MAX`: they run until the horizon.
    pub duration_steps: u64,
    pub policy: JobPolicy,
    /// Workload class; [`JobClass::Training`] preserves the pre-serving
    /// engine bit-for-bit.
    pub class: JobClass,
    /// Latency SLO; only meaningful for serving jobs.
    pub slo: Option<SloSpec>,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            id: 0,
            arrival_step: 0,
            w: 2,
            h: 2,
            duration_steps: 1,
            policy: JobPolicy::Continue,
            class: JobClass::Training,
            slo: None,
        }
    }
}

impl JobSpec {
    pub fn chips(&self) -> usize {
        self.w * self.h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_policy_names_roundtrip() {
        for p in JobPolicy::ALL {
            assert_eq!(JobPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(JobPolicy::parse("??"), None);
    }
}
