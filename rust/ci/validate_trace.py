#!/usr/bin/env python3
"""Validate a Chrome/Perfetto trace-event JSON emitted by --trace.

Checks, beyond "it parses":
  - top-level shape: displayTimeUnit + a traceEvents list
  - timestamps are monotone non-decreasing across the event stream
  - complete ("X") spans have non-negative durations and nest properly
    per (pid, tid) track
  - async ("b"/"e") recovery spans are balanced per (pid, id) and each
    end is at or after its begin
  - the trace carries real content: at least one complete span, and at
    least one recovery-category event (the fleet CI invocation runs
    with failures, so recoveries must appear)

Exits non-zero with a message on the first violation; prints a short
summary on success.  Stdlib only.
"""

import json
import sys

EPS = 1e-6  # float slack when comparing microsecond stamps


def fail(msg):
    print(f"trace validation FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def main(path):
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {path}: {e}")

    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail("top level must be an object with a traceEvents list")
    if doc.get("displayTimeUnit") not in ("ms", "ns"):
        fail(f"unexpected displayTimeUnit {doc.get('displayTimeUnit')!r}")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        fail("traceEvents must be a non-empty list")

    spans = 0
    instants = 0
    recovery_events = 0
    last_ts = None
    # Per-(pid, tid) stack of X-span end times for nesting checks.
    open_spans = {}
    # Per-(pid, id) stack of begin timestamps for async balance.
    open_async = {}

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(f"event {i} is not an object")
        ph = ev.get("ph")
        if ph == "M":
            continue  # metadata records carry no timestamp ordering
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            fail(f"event {i} ({ev.get('name')!r}) has no numeric ts")
        if last_ts is not None and ts < last_ts - EPS:
            fail(f"event {i} ts {ts} precedes previous ts {last_ts}")
        last_ts = ts
        if ev.get("cat") == "recovery":
            recovery_events += 1
        track = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            spans += 1
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(f"X span {i} ({ev.get('name')!r}) has bad dur {dur!r}")
            stack = open_spans.setdefault(track, [])
            # Pop finished enclosing spans, then require containment.
            while stack and ts >= stack[-1] - EPS:
                stack.pop()
            if stack and ts + dur > stack[-1] + EPS:
                fail(
                    f"X span {i} ({ev.get('name')!r}) on track {track} "
                    f"overflows its enclosing span"
                )
            stack.append(ts + dur)
        elif ph == "b":
            key = (ev.get("pid"), ev.get("id"))
            open_async.setdefault(key, []).append(ts)
        elif ph == "e":
            key = (ev.get("pid"), ev.get("id"))
            stack = open_async.get(key)
            if not stack:
                fail(f"async end {i} ({ev.get('name')!r}) id {key} has no begin")
            begin = stack.pop()
            if ts < begin - EPS:
                fail(f"async end {i} at {ts} precedes its begin at {begin}")
        elif ph == "i":
            instants += 1
        else:
            fail(f"event {i} has unknown phase {ph!r}")

    unclosed = [k for k, v in open_async.items() if v]
    if unclosed:
        fail(f"{len(unclosed)} async span(s) never ended, e.g. id {unclosed[0]}")
    if spans == 0:
        fail("trace contains no complete (X) spans")
    if recovery_events == 0:
        fail("trace contains no recovery-category events")

    print(
        f"trace OK: {len(events)} events, {spans} spans, {instants} instants, "
        f"{recovery_events} recovery events"
    )


if __name__ == "__main__":
    if len(sys.argv) != 2:
        print("usage: validate_trace.py TRACE.json", file=sys.stderr)
        sys.exit(2)
    main(sys.argv[1])
