//! Scheme ablation (DESIGN.md experiment E13): the four allreduce
//! algorithms of paper §2 compared on (a) numeric correctness, (b)
//! schedule shape, (c) simulated time on the TPU-v3 link model — on a
//! full 8x8 mesh and on the same mesh with a failed 4x2 host.
//!
//!     cargo run --release --example scheme_comparison

use meshreduce::collective::verify::{check_allreduce, schedule_cdg_acyclic};
use meshreduce::collective::{build_schedule, Scheme};
use meshreduce::mesh::{FailedRegion, Topology};
use meshreduce::simnet::{simulate, LinkModel};
use meshreduce::util::fmt::{format_bytes, format_duration_s};

fn compare(topo: &Topology, label: &str, payload: usize) {
    let link = LinkModel::tpu_v3();
    println!(
        "\n=== {label}: {} live chips, payload {} ===",
        topo.live_count(),
        format_bytes(4 * payload as u64)
    );
    println!(
        "{:15} {:>8} {:>10} {:>12} {:>10} {:>8} {:>8}",
        "scheme", "steps", "transfers", "sim time", "algbw", "numeric", "CDG"
    );
    for scheme in Scheme::ALL {
        match build_schedule(scheme, topo, payload) {
            Ok(sched) => {
                let report = simulate(&sched, topo, &link).expect("simulate");
                let ok = check_allreduce(&sched, topo, 7).is_empty();
                let cdg = schedule_cdg_acyclic(&sched, topo);
                println!(
                    "{:15} {:>8} {:>10} {:>12} {:>7.1} GB/s {:>8} {:>8}",
                    scheme.name(),
                    sched.num_steps(),
                    sched.num_transfers(),
                    format_duration_s(report.makespan_s),
                    report.algorithm_bandwidth(4 * payload as u64) / 1e9,
                    if ok { "OK" } else { "FAIL" },
                    if cdg { "acyclic" } else { "CYCLIC" },
                );
            }
            Err(e) => println!("{:15} unsupported: {e}", scheme.name()),
        }
    }
}

fn main() {
    let payload = 1 << 22; // 16 MiB of f32 — bandwidth-bound regime
    compare(&Topology::full(8, 8), "full 8x8 mesh", payload);
    compare(
        &Topology::with_failure(8, 8, FailedRegion::host(2, 2)),
        "8x8 mesh with failed 4x2 host",
        payload,
    );

    // Latency-bound regime: tiny payload, where step count dominates.
    compare(&Topology::full(8, 8), "full 8x8 mesh (latency-bound)", 1 << 10);

    println!(
        "\nreading: pair-rows/fault-tolerant keep phase-1 rings link-disjoint (high\n\
         algbw); the 1-D ring pays O(N^2) steps; the basic 2-D scheme shares links\n\
         between its two colour flips — exactly the trade-offs of paper §2."
    );
}
