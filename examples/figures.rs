//! Render the paper's figures (Figures 1-10) as ASCII diagrams
//! (DESIGN.md experiments E3-E9).
//!
//!     cargo run --example figures            # all figures
//!     cargo run --example figures -- fig9    # one figure

use meshreduce::figures::all_figures;

fn main() {
    let wanted: Vec<String> = std::env::args().skip(1).collect();
    for (name, body) in all_figures() {
        if wanted.is_empty() || wanted.iter().any(|w| w == name) {
            println!("==== {name} ====\n{body}");
        }
    }
}
