//! MTBF availability sweep (EXPERIMENTS.md §Sweep): replay seeded
//! failure/repair timelines under every recovery policy and compare
//! effective training throughput — the paper's availability argument
//! measured over a whole process, not one scripted failure.
//!
//!     cargo run --release --example mtbf_sweep            # small 8x8 demo grid
//!     cargo run --release --example mtbf_sweep -- --paper # 16x32, 8 seeds x 3 MTBF points
//!
//! Writes `BENCH_sweep.json` (path override: `MESHREDUCE_BENCH_JSON`).
//! Every step-time prediction flows through the topology-keyed plan
//! cache, so the sweep is simulation-bound: revisited topologies are
//! cache hits and adjacent ones recompile incrementally — the printed
//! hit rates are the point of the exercise.

use meshreduce::cluster::{curves, run_sweep, SweepConfig};
use meshreduce::util::bench::{quick_mode, JsonReport};

fn main() -> anyhow::Result<()> {
    let paper = std::env::args().any(|a| a == "--paper");
    let mut cfg = if paper { SweepConfig::paper_scale() } else { SweepConfig::quick() };
    if !paper && !quick_mode() {
        // The default demo is a little richer than the CI grid.
        cfg.horizon = 400;
        cfg.seeds = vec![0, 1, 2];
        cfg.mtbf_points = vec![80.0, 40.0];
    }

    println!(
        "MTBF sweep on a {}x{} mesh: horizon {} steps, MTTR fractions {:?}, \
         region shapes {:?}, {} seeds x {} MTBF points x {} policies",
        cfg.nx,
        cfg.ny,
        cfg.horizon,
        cfg.mttr_fracs,
        cfg.regions,
        cfg.seeds.len(),
        cfg.mtbf_points.len(),
        cfg.policies.len(),
    );
    println!(
        "effective throughput = delivered worker-steps / wall seconds (per-chip batch is fixed);\n\
         transition costs modelled as {} rebuild steps (fault-tolerant) and {} restart steps +\n\
         checkpoint rollback (restarts)\n",
        cfg.rebuild_steps, cfg.restart_steps,
    );

    let points = run_sweep(&cfg)?;
    let mut report = JsonReport::new();
    for p in &points {
        println!(
            "  {:<16} mtbf {:>5.0} seed {:>2}: {:>9.1} w-steps/s ({:.4} of healthy), \
             {:>3} transitions, cache hit-rate {:.3} ({} incremental compiles)",
            p.policy.name(),
            p.mtbf_steps,
            p.seed,
            p.eff_throughput,
            p.normalized(),
            p.transitions,
            p.cache.hit_rate(),
            p.cache.incremental_compiles,
        );
        report.push(
            &format!(
                "{}_mtbf{:.0}_mttr{:.2}_{}x{}_seed{}",
                p.policy.name(),
                p.mtbf_steps,
                p.mttr_frac,
                p.region.0,
                p.region.1,
                p.seed
            ),
            if p.eff_throughput > 0.0 { 1.0 / p.eff_throughput } else { 0.0 },
            0.0,
            &[
                ("eff_throughput", p.eff_throughput),
                ("normalized", p.normalized()),
                ("mtbf_steps", p.mtbf_steps),
                ("mttr_frac", p.mttr_frac),
                ("transitions", p.transitions as f64),
                ("cache_hit_rate", p.cache.hit_rate()),
                ("incremental_compiles", p.cache.incremental_compiles as f64),
                ("mean_compile_s", p.cache.mean_compile_s()),
            ],
        );
    }

    println!("\nper-policy curves (mean over seeds):");
    for c in curves(&points) {
        println!(
            "  {:<16} mtbf {:>5.0}: {:>9.1} w-steps/s = {:.4} of healthy (hit-rate {:.3})",
            c.policy.name(),
            c.mtbf_steps,
            c.mean_eff,
            c.mean_normalized,
            c.mean_hit_rate,
        );
        report.push(
            &format!(
                "curve_{}_mtbf{:.0}_mttr{:.2}_{}x{}",
                c.policy.name(),
                c.mtbf_steps,
                c.mttr_frac,
                c.region.0,
                c.region.1
            ),
            if c.mean_eff > 0.0 { 1.0 / c.mean_eff } else { 0.0 },
            0.0,
            &[
                ("mean_eff_throughput", c.mean_eff),
                ("mean_normalized", c.mean_normalized),
                ("mtbf_steps", c.mtbf_steps),
                ("mttr_frac", c.mttr_frac),
                ("mean_cache_hit_rate", c.mean_hit_rate),
            ],
        );
    }

    let written = report.write("BENCH_sweep.json")?;
    println!("\nsweep record written to {written}");
    Ok(())
}
