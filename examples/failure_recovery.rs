//! Availability demo (DESIGN.md experiment E11, extended by PR 2): a
//! scenario-script timeline — two temporally overlapping failed
//! regions, then a repair/rejoin — replayed under every recovery
//! policy, compared against the alternatives from the paper's
//! introduction.
//!
//!     cargo run --release --example failure_recovery
//!     cargo run --release --example failure_recovery -- --scenario my.scenario
//!
//! Two layers:
//!
//! 1. **Model-driven availability record** (always runs, no PJRT or
//!    artifacts needed): replays the scenario through the cluster
//!    control plane, predicts steps/sec before, during and after each
//!    fault with `perfmodel::steptime`, measures the ring-rebuild +
//!    plan-recompile recovery latency, and writes `BENCH_recovery.json`
//!    (path override: `MESHREDUCE_BENCH_JSON`).
//! 2. **Live training comparison** (when the PJRT runtime and the tiny
//!    model artifacts are available): the same scenario driven end to
//!    end through the coordinator under fault-tolerant, sub-mesh,
//!    adaptive and stop policies.

use meshreduce::cluster::{ClusterEvent, ClusterState, Scenario};
use meshreduce::collective::{build_schedule, CompiledSchedule, PlanCache, Scheme};
use meshreduce::coordinator::policy::{largest_submesh, spare_overhead, RecoveryPolicy};
use meshreduce::coordinator::{Coordinator, JobConfig};
use meshreduce::perfmodel::predict_candidate;
use meshreduce::runtime::Runtime;
use meshreduce::simnet::{validate_routes, LinkModel};
use meshreduce::trainer::TrainerConfig;
use meshreduce::util::bench::{bench, quick_mode, JsonReport};

const STEPS: u64 = 24;
const DEFAULT_SCENARIO: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/scenarios/two_fail_one_repair.scenario");
/// Payload of the model-driven record: 4 MiB of f32 gradients.
const MODEL_PAYLOAD: usize = 1 << 20;
/// Nominal per-worker compute time for the model-driven record.
const MODEL_COMPUTE_S: f64 = 0.05;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let path = args
        .iter()
        .position(|a| a == "--scenario")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or(DEFAULT_SCENARIO);
    let sc = Scenario::load(std::path::Path::new(path))?;
    let (nx, ny) = sc.mesh.unwrap_or((8, 8));
    println!(
        "failure-recovery scenario {path}: {} events on a {nx}x{ny} mesh",
        sc.events.len()
    );

    let record = model_driven_record(&sc, nx, ny)?;
    let written = record.write("BENCH_recovery.json")?;
    println!("\nrecovery bench record written to {written}");

    match Runtime::cpu() {
        Ok(runtime) => {
            let policies = [
                RecoveryPolicy::FaultTolerant,
                RecoveryPolicy::SubMesh,
                RecoveryPolicy::Adaptive,
                RecoveryPolicy::Stop,
            ];
            for policy in policies {
                run_policy(&runtime, &sc, nx, ny, policy)?;
            }
            cost_summary(&sc, nx, ny);
        }
        Err(e) => {
            println!("\nPJRT unavailable ({e}); skipping the live training comparison");
        }
    }
    Ok(())
}

/// Replay the scenario on the cluster ledger and record predicted
/// steps/sec before, during and after each fault plus the measured
/// recovery latency (ring rebuild + plan recompile + route cache).
fn model_driven_record(sc: &Scenario, nx: usize, ny: usize) -> anyhow::Result<JsonReport> {
    let link = LinkModel::tpu_v3();
    let mut cluster = ClusterState::new(nx, ny);
    let mut report = JsonReport::new();
    let mut cache = PlanCache::new(16);
    let iters = if quick_mode() { 3 } else { 10 };

    let healthy = predict_candidate(&cluster.topology(), MODEL_PAYLOAD, &link, MODEL_COMPUTE_S)?;
    println!(
        "\nmodel-driven record (payload {} f32, compute {MODEL_COMPUTE_S}s/worker):",
        MODEL_PAYLOAD
    );
    println!(
        "  steady state       : {:3} workers, {:.4}s/step = {:.2} steps/s",
        healthy.workers,
        healthy.step_s,
        1.0 / healthy.step_s
    );
    report.push(
        "steady_full_mesh",
        healthy.step_s,
        4.0 * MODEL_PAYLOAD as f64 / healthy.allreduce_s / 1e9,
        &[
            ("steps_per_s", 1.0 / healthy.step_s),
            ("workers", healthy.workers as f64),
            ("throughput", healthy.throughput),
        ],
    );

    for (stage, ev) in sc.events.iter().enumerate() {
        if matches!(ev.event, ClusterEvent::CheckpointTick | ClusterEvent::Stop) {
            continue;
        }
        cluster
            .apply(&ev.event)
            .map_err(|e| anyhow::anyhow!("scenario step {stage} invalid: {e}"))?;
        let topo = cluster.topology();
        // Recovery latency: what the trainer pays on the transition —
        // rebuild the fault-tolerant rings, recompile the schedule and
        // re-resolve the route cache on the new topology.
        let mut plan: Option<CompiledSchedule> = None;
        let rebuild = bench(&format!("rebuild stage {stage}"), 1, iters, || {
            let sched =
                build_schedule(Scheme::FaultTolerant, &topo, MODEL_PAYLOAD).expect("schedulable");
            plan = Some(CompiledSchedule::compile(&sched, &topo).expect("routable"));
        });
        // Multi-hole gate: every cached route must dodge every hole.
        validate_routes(plan.as_ref().expect("plan built"), &topo)?;

        // The recompilation fast path: the same transition served by
        // the topology-keyed plan cache (hit, or incremental recompile
        // from the previous stage's plan) instead of a cold rebuild.
        let t0 = std::time::Instant::now();
        let _cached = cache.get(Scheme::FaultTolerant, &topo, MODEL_PAYLOAD)?;
        let cache_get_s = t0.elapsed().as_secs_f64();

        let p = predict_candidate(&topo, MODEL_PAYLOAD, &link, MODEL_COMPUTE_S)?;
        println!(
            "  after {:7} @{:2} : {:3} workers, {:.4}s/step = {:.2} steps/s \
             (rebuild {:.4}s, cached {:.5}s)",
            ev.event.name(),
            ev.at_step,
            p.workers,
            p.step_s,
            1.0 / p.step_s,
            rebuild.mean_s(),
            cache_get_s,
        );
        report.push(
            &format!("stage{stage}_{}", ev.event.name()),
            p.step_s,
            4.0 * MODEL_PAYLOAD as f64 / p.allreduce_s / 1e9,
            &[
                ("steps_per_s", 1.0 / p.step_s),
                ("workers", p.workers as f64),
                ("throughput", p.throughput),
                ("recovery_latency_s", rebuild.mean_s()),
                ("plan_cache_get_s", cache_get_s),
            ],
        );
    }

    // Cache effectiveness over the whole scenario: hit rate, the
    // incremental/full compile split and mean compile latency.
    let s = cache.stats();
    println!(
        "  plan cache         : {}/{} hits ({:.0}%), {} incremental + {} full compiles, \
         mean compile {:.4}s",
        s.hits,
        s.lookups(),
        100.0 * s.hit_rate(),
        s.incremental_compiles,
        s.full_compiles,
        s.mean_compile_s(),
    );
    report.push(
        "plan_cache",
        s.mean_compile_s(),
        0.0,
        &[
            ("hits", s.hits as f64),
            ("lookups", s.lookups() as f64),
            ("hit_rate", s.hit_rate()),
            ("incremental_compiles", s.incremental_compiles as f64),
            ("full_compiles", s.full_compiles as f64),
            ("validation_evictions", s.validation_evictions as f64),
        ],
    );
    Ok(report)
}

/// Drive the scenario end to end through the coordinator.
fn run_policy(
    runtime: &Runtime,
    sc: &Scenario,
    nx: usize,
    ny: usize,
    policy: RecoveryPolicy,
) -> anyhow::Result<()> {
    let mut tcfg = TrainerConfig::new("tiny", nx, ny);
    tcfg.verify_allreduce = true;
    let mut job = JobConfig::new(tcfg, STEPS);
    job.policy = policy;
    job.checkpoint_every = Some(8);
    job.events = sc.events.clone();

    println!("\n--- policy: {} ---", policy.name());
    let mut coord = match Coordinator::new(job, runtime) {
        Ok(c) => c,
        Err(e) => {
            println!("setup skipped: {e}");
            return Ok(());
        }
    };
    match coord.run() {
        Ok(s) => {
            println!(
                "completed {} steps; workers {} -> {}; final loss {:.4}",
                s.steps_run,
                nx * ny,
                s.final_workers,
                s.final_loss
            );
            for (step, e) in &s.events {
                println!("  @step {step}: {e}");
            }
        }
        Err(e) => println!("stopped: {e}"),
    }
    Ok(())
}

/// The paper §1 cost comparison at the scenario's deepest degradation.
fn cost_summary(sc: &Scenario, nx: usize, ny: usize) {
    let mut cluster = ClusterState::new(nx, ny);
    let mut worst_failed = 0usize;
    let mut worst_regions = Vec::new();
    for ev in &sc.events {
        if cluster.apply(&ev.event).is_ok() {
            let failed = nx * ny - cluster.live_chips();
            if failed >= worst_failed {
                worst_failed = failed;
                worst_regions = cluster.failed_regions().to_vec();
            }
        }
    }
    let sub = largest_submesh(nx, ny, &worst_regions);
    println!("\n--- cost summary (paper §1's four options, at the deepest point) ---");
    println!(
        "fault-tolerant : keeps {}/{} chips running (this paper)",
        nx * ny - worst_failed,
        nx * ny
    );
    println!(
        "sub-mesh       : falls back to {}x{} = {} chips + loses steps since checkpoint",
        sub.2,
        sub.3,
        sub.2 * sub.3
    );
    println!(
        "hot spares     : needs ~{:.1}% extra chips provisioned permanently",
        100.0 * spare_overhead(nx, ny)
    );
    println!("stop           : zero chips until repair");
}
