//! Availability demo (DESIGN.md experiment E11): a 4x2 host (8 chips)
//! dies mid-training and the job keeps going — the paper's headline
//! availability claim — compared against the "sub-mesh restart"
//! alternative from the paper's introduction.
//!
//!     cargo run --release --example failure_recovery

use meshreduce::coordinator::policy::{largest_submesh, spare_overhead, RecoveryPolicy};
use meshreduce::coordinator::{Coordinator, FailureEvent, JobConfig};
use meshreduce::mesh::FailedRegion;
use meshreduce::runtime::Runtime;
use meshreduce::trainer::TrainerConfig;

const MESH: (usize, usize) = (8, 8);
const STEPS: u64 = 24;
const FAIL_AT: u64 = 10;

fn run_policy(runtime: &Runtime, policy: RecoveryPolicy) -> anyhow::Result<()> {
    let region = FailedRegion::host(2, 4); // 4x2, 8 chips — as in the paper
    let mut tcfg = TrainerConfig::new("tiny", MESH.0, MESH.1);
    tcfg.verify_allreduce = true;
    let mut job = JobConfig::new(tcfg, STEPS);
    job.policy = policy;
    job.checkpoint_every = Some(8);
    job.failures = vec![FailureEvent { at_step: FAIL_AT, region }];

    println!("\n--- policy: {} ---", policy.name());
    let mut coord = Coordinator::new(job, runtime)?;
    match coord.run() {
        Ok(s) => {
            println!(
                "completed {} steps; workers {} -> {}; final loss {:.4}",
                s.steps_run,
                MESH.0 * MESH.1,
                s.final_workers,
                s.final_loss
            );
            for (step, e) in &s.events {
                println!("  @step {step}: {e}");
            }
            // Show the loss around the failure: continuity is the point.
            println!("  loss around the failure:");
            for r in &coord.trainer.metrics.records {
                if (FAIL_AT.saturating_sub(2)..FAIL_AT + 3).contains(&r.step) {
                    println!("    step {:>2}: loss {:.4}  ({} workers)", r.step, r.loss, r.workers);
                }
            }
        }
        Err(e) => println!("stopped: {e}"),
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let runtime = Runtime::cpu()?;
    println!(
        "failure-recovery comparison on an {}x{} mesh, 4x2 host failure at step {FAIL_AT}",
        MESH.0, MESH.1
    );

    // The paper's scheme: rebuild fault-tolerant rings, keep training.
    run_policy(&runtime, RecoveryPolicy::FaultTolerant)?;

    // Alternative 1: restart on the largest clean sub-mesh.
    run_policy(&runtime, RecoveryPolicy::SubMesh)?;

    // Alternative 2: stop and wait for repair.
    run_policy(&runtime, RecoveryPolicy::Stop)?;

    // Alternative 3 (analytic): hot spares avoid the failure entirely
    // but cost extra chips all the time.
    let region = FailedRegion::host(2, 4);
    let sub = largest_submesh(MESH.0, MESH.1, &region);
    println!("\n--- cost summary (paper §1's four options) ---");
    println!(
        "fault-tolerant : keeps {}/{} chips running (this paper)",
        MESH.0 * MESH.1 - region.num_chips(),
        MESH.0 * MESH.1
    );
    println!(
        "sub-mesh       : falls back to {}x{} = {} chips + loses steps since checkpoint",
        sub.2,
        sub.3,
        sub.2 * sub.3
    );
    println!(
        "hot spares     : needs ~{:.1}% extra chips provisioned permanently",
        100.0 * spare_overhead(MESH.0, MESH.1)
    );
    println!("stop           : zero chips until repair");
    Ok(())
}
