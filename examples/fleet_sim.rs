//! Fleet scheduler walk-through (EXPERIMENTS.md §Fleet): replay one
//! seeded multi-job workload × MTBF timeline under each recovery
//! policy and compare utilization, job completion time and goodput —
//! the paper's availability argument generalised from one job to a
//! whole fleet sharing the mesh.
//!
//!     cargo run --release --example fleet_sim            # reduced 16x32 fleet
//!     cargo run --release --example fleet_sim -- --paper # full paper-scale fleet
//!
//! Writes `BENCH_fleet.json` (path override: `MESHREDUCE_BENCH_JSON`).
//! Also demonstrates plan-cache persistence (the warmed process-wide
//! cache is saved, re-loaded, and the reloaded run's first visits
//! become hits) and the wall-clock engine: the contention-off replay
//! is checked bit-identical to round-robin (EXPERIMENTS.md
//! §Contention), then contention is switched on and the
//! dilation/hotspot figures are printed and recorded.

use meshreduce::sched::{
    metrics, run_fleet, run_with_cache, ClockMode, ContentionModel, FleetConfig, JobPolicy,
};
use meshreduce::util::bench::JsonReport;

fn main() -> anyhow::Result<()> {
    let paper = std::env::args().any(|a| a == "--paper");
    let mut cfg = if paper { FleetConfig::paper_scale() } else { FleetConfig::quick() };
    if !paper {
        cfg.horizon = 300;
        cfg.payload = 1 << 13;
    }

    let jobs = cfg.workload.generate();
    println!(
        "fleet on a {}x{} mesh ({} chips): {} jobs, horizon {} fleet steps",
        cfg.nx,
        cfg.ny,
        cfg.nx * cfg.ny,
        jobs.len(),
        cfg.horizon
    );
    println!("\nworkload (seed {}):", cfg.workload.seed);
    for j in &jobs {
        println!(
            "  job {}: {}x{} ({} chips), arrives t={}, {} steps of work",
            j.id,
            j.w,
            j.h,
            j.chips(),
            j.arrival_step,
            j.duration_steps
        );
    }

    let policies = [JobPolicy::Continue, JobPolicy::Migrate, JobPolicy::Adaptive];
    let mut report = JsonReport::new();
    let mut warmed = None;
    let mut reference = None;
    println!("\nper-policy comparison (same workload, same failures):");
    for p in policies {
        let mut c = cfg.clone();
        c.policy = Some(p);
        let (run, cache) = run_with_cache(&c)?;
        let s = &run.summary;
        println!(
            "  {:<12} goodput {:>8.1} w-steps/step, utilization {:.3}, mean JCT {:>6.1}, \
             {}/{} done, {} migrations, {} shrinks, {} ft-continues, {} waits \
             (cache hit-rate {:.3}, splice rate {:.3})",
            run.label,
            s.goodput,
            s.mean_utilization,
            s.mean_jct,
            s.completed,
            s.arrivals,
            s.migrations,
            s.shrinks,
            s.ft_continues,
            s.queue_waits,
            s.cache.hit_rate(),
            s.cache.step_splice_rate(),
        );
        metrics::push_run(&mut report, &run);
        if warmed.is_none() {
            // Keep the first policy's annotated event log + cache, and
            // its run as the wall-clock differential reference below.
            for (t, e) in run.events.iter().take(12) {
                println!("      [t={t:>4}] {e}");
            }
            warmed = Some(cache);
            reference = Some(run);
        }
    }
    let reference = reference.expect("at least one policy ran");

    // Plan-cache persistence round-trip: save the warmed cache, reload
    // it, and re-run — first visits to persisted topologies are hits.
    if let Some(cache) = warmed {
        let path = std::env::temp_dir().join("meshreduce_fleet_sim.plans");
        let saved = cache.save(&path, 64)?;
        let loaded = meshreduce::collective::PlanCache::load(&path, 64)?;
        let mut c = cfg.clone();
        c.policy = Some(JobPolicy::Continue);
        c.seed_cache = Some(loaded);
        let (rerun, _) = run_with_cache(&c)?;
        println!(
            "\nplan-cache persistence: {} entries saved to {}; warm re-run hit-rate {:.3} \
             ({} loaded entries served)",
            saved,
            path.display(),
            rerun.summary.cache.hit_rate(),
            rerun.summary.cache.persist_loaded,
        );
    }

    // Wall-clock engine: differential check against the round-robin
    // Continue run already computed above, then the contention-on
    // replay with dilation + hotspot curves.
    let mut wall = cfg.clone();
    wall.policy = Some(JobPolicy::Continue);
    wall.clock = ClockMode::WallClock;
    let wall_run = run_fleet(&wall)?;
    anyhow::ensure!(
        reference.summary.goodput.to_bits() == wall_run.summary.goodput.to_bits()
            && reference.events == wall_run.events,
        "wall-clock engine (contention off) must replay round-robin bit-for-bit"
    );
    println!(
        "\nwall-clock differential: goodput {:.1} == round-robin {:.1} (bit-identical trace)",
        wall_run.summary.goodput, reference.summary.goodput
    );

    let mut contended = wall.clone();
    contended.contention = Some(ContentionModel::tpu_default());
    let mut run = run_fleet(&contended)?;
    run.label = "wall-contended".to_string();
    let s = &run.summary;
    println!(
        "wall-clock + contention: goodput {:.1}, mean dilation {:.4}, max dilation {:.4}, \
         {} link epochs",
        s.goodput, s.mean_dilation, s.max_dilation, s.contention_epochs
    );
    for h in run.hotspots.iter().take(4) {
        println!(
            "  hotspot ({},{}) {}: mean occupancy {:.3}",
            h.x,
            h.y,
            h.dir_name(),
            h.mean_occupancy
        );
    }
    metrics::push_run(&mut report, &run);

    let written = report.write("BENCH_fleet.json")?;
    println!("\nfleet record written to {written}");
    Ok(())
}
