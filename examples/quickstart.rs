//! Quickstart: train the `tiny` transformer on a 2x2 mesh for a handful
//! of steps with the fault-tolerant allreduce, then print the loss
//! curve.
//!
//!     make artifacts && cargo run --release --example quickstart

use meshreduce::coordinator::{Coordinator, JobConfig};
use meshreduce::runtime::Runtime;
use meshreduce::trainer::TrainerConfig;

fn main() -> anyhow::Result<()> {
    // 1. PJRT CPU client (loads the AOT HLO artifacts; python is not
    //    involved at runtime).
    let runtime = Runtime::cpu()?;
    println!("PJRT platform: {}", runtime.platform());

    // 2. A 2x2 mesh of data-parallel workers training the tiny model.
    let mut tcfg = TrainerConfig::new("tiny", 2, 2);
    tcfg.verify_allreduce = true; // check every step's global sum
    let mut job = JobConfig::new(tcfg, 10);
    job.log_every = 1;

    // 3. Run.
    let mut coord = Coordinator::new(job, &runtime)?;
    let summary = coord.run()?;

    println!("\nloss curve:");
    for r in &coord.trainer.metrics.records {
        println!(
            "  step {:>2}  loss {:.4}  (compute {:>7.1}ms, allreduce {:>6.2}ms)",
            r.step,
            r.loss,
            r.compute_s * 1e3,
            r.allreduce_s * 1e3
        );
    }
    println!(
        "\nfinal loss {:.4} after {} steps on {} workers — allreduce verified every step",
        summary.final_loss, summary.steps_run, summary.final_workers
    );
    Ok(())
}
