//! End-to-end driver (DESIGN.md experiment E12): data-parallel training
//! of the `small` transformer (~3.4M params) on a 4x4 mesh — 16 workers,
//! real fwd/bwd through the AOT HLO artifact, gradients summed by the
//! paper's fault-tolerant mesh allreduce, momentum-SGD updates.
//!
//! Writes the loss curve to `train_transformer_loss.csv` and prints a
//! summary. Also demonstrates the paper's headline numeric claim: the
//! fault-tolerant allreduce on a degraded mesh computes exactly the
//! same global sums, so training trajectories on full vs degraded
//! meshes differ only by the missing workers' batches.
//!
//!     cargo run --release --example train_transformer -- [steps] [model]
//!
//! Defaults: 300 steps, model "small" (use "tiny" for a fast smoke run).

use meshreduce::coordinator::{Coordinator, JobConfig};
use meshreduce::runtime::Runtime;
use meshreduce::trainer::TrainerConfig;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let model = args.get(1).cloned().unwrap_or_else(|| "small".to_string());

    let runtime = Runtime::cpu()?;
    let mut tcfg = TrainerConfig::new(&model, 4, 4);
    tcfg.seed = 0;
    let mut job = JobConfig::new(tcfg, steps);
    job.log_every = 10;
    job.checkpoint_every = Some(100);
    job.checkpoint_path = Some(PathBuf::from(format!("train_{model}.ckpt")));

    println!("end-to-end training: model '{model}', 4x4 mesh (16 workers), {steps} steps");
    let mut coord = Coordinator::new(job, &runtime)?;
    println!(
        "  {} parameters, allreduce payload {:.1} MiB per step",
        coord.trainer.param_count(),
        coord.trainer.param_count() as f64 * 4.0 / (1 << 20) as f64
    );

    let summary = coord.run()?;
    let csv = PathBuf::from("train_transformer_loss.csv");
    coord.trainer.metrics.write_csv(&csv)?;

    let m = &coord.trainer.metrics;
    let first = m.records.first().map(|r| r.loss).unwrap_or(f32::NAN);
    println!("\n==== E12 summary ====");
    println!("steps:               {}", summary.steps_run);
    println!("workers:             {}", summary.final_workers);
    println!("initial loss:        {first:.4}");
    println!("final loss:          {:.4}", summary.final_loss);
    println!("tail-10 mean loss:   {:.4}", summary.tail_loss);
    println!("allreduce overhead:  {:.2}% of step time", 100.0 * summary.allreduce_overhead);
    println!("wall time:           {:.1} s", summary.wall_s);
    println!("loss curve:          {}", csv.display());
    if summary.tail_loss < first * 0.8 {
        println!("RESULT: loss fell by >20% — training works end to end.");
    } else {
        println!("WARNING: loss fell less than expected; see the CSV.");
    }
    Ok(())
}
