"""AOT exporter: lowers the L2/L1 computations to HLO **text** artifacts
that the Rust coordinator loads via the PJRT C API.

Interchange format is HLO text, not serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Artifacts per model config <cfg>:
  artifacts/train_step.<cfg>.hlo.txt   (flat_params, tokens) -> (loss, flat_grads)
  artifacts/sgd_update.<cfg>.hlo.txt   (params, grads, velocity) -> (params', velocity')
  artifacts/init_params.<cfg>.bin      f32 LE initial flat parameters
  artifacts/model.<cfg>.meta           key/value lines (shapes, hyperparams)
Plus the standalone paper-hot-spot kernel:
  artifacts/combine.hlo.txt            (a, b) -> a + b   (Pallas, 2^16 elems)
  artifacts/combine.meta

Usage: python -m compile.aot [--out-dir ../artifacts] [--configs tiny,small]
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.combine import combine
from .model import CONFIGS, init_params, param_count, sgd_step, train_step

COMBINE_ELEMS = 1 << 16


def to_hlo_text(fn, *specs) -> str:
    """Lower a jittable fn at the given ShapeDtypeStructs to HLO text."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_config(cfg_name: str, out_dir: str) -> dict:
    cfg = CONFIGS[cfg_name]
    pcount = param_count(cfg)
    fp = jax.ShapeDtypeStruct((pcount,), jnp.float32)
    toks = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)

    paths = {}

    step_hlo = to_hlo_text(train_step(cfg), fp, toks)
    paths["train_step"] = _write(out_dir, f"train_step.{cfg_name}.hlo.txt", step_hlo)

    sgd_hlo = to_hlo_text(sgd_step(cfg), fp, fp, fp)
    paths["sgd_update"] = _write(out_dir, f"sgd_update.{cfg_name}.hlo.txt", sgd_hlo)

    init = np.asarray(init_params(cfg, seed=0), dtype=np.float32)
    init_path = os.path.join(out_dir, f"init_params.{cfg_name}.bin")
    init.tofile(init_path)
    paths["init_params"] = init_path

    meta = {
        "config": cfg_name,
        "param_count": pcount,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "use_pallas": int(cfg.use_pallas),
        "lr": cfg.lr,
        "momentum": cfg.momentum,
    }
    meta_text = "".join(f"{k} {v}\n" for k, v in meta.items())
    paths["meta"] = _write(out_dir, f"model.{cfg_name}.meta", meta_text)
    return paths


def export_combine(out_dir: str) -> dict:
    spec = jax.ShapeDtypeStruct((COMBINE_ELEMS,), jnp.float32)
    hlo = to_hlo_text(lambda a, b: combine(a, b), spec, spec)
    p1 = _write(out_dir, "combine.hlo.txt", hlo)
    p2 = _write(out_dir, "combine.meta", f"elems {COMBINE_ELEMS}\n")
    return {"combine": p1, "combine_meta": p2}


def _write(out_dir: str, name: str, text: str) -> str:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    all_paths = export_combine(args.out_dir)
    for cfg_name in args.configs.split(","):
        cfg_name = cfg_name.strip()
        if cfg_name not in CONFIGS:
            raise SystemExit(f"unknown config {cfg_name!r}; have {sorted(CONFIGS)}")
        print(f"[aot] exporting config {cfg_name} "
              f"({param_count(CONFIGS[cfg_name]):,} params)...")
        all_paths.update(
            {f"{cfg_name}.{k}": v for k, v in export_config(cfg_name, args.out_dir).items()}
        )

    manifest = "".join(f"{k} {os.path.basename(v)}\n" for k, v in sorted(all_paths.items()))
    _write(args.out_dir, "MANIFEST", manifest)
    for k, v in sorted(all_paths.items()):
        size = os.path.getsize(v)
        print(f"[aot] {k:24s} -> {v} ({size:,} B)")


if __name__ == "__main__":
    main()
