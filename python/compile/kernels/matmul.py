"""L1 Pallas kernel: MXU-tiled matmul.

The TPU hardware adaptation of the model's compute hot path: (128, 128)
output tiles match the MXU systolic array; the K dimension is walked by
the grid's innermost axis with an f32 accumulator held in the output
block (VMEM-resident across the K loop because the output BlockSpec
index is independent of the K grid axis).

``interpret=True`` always: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the same kernel to portable HLO so
the AOT artifacts execute anywhere (see /opt/xla-example/README.md).

VMEM footprint per grid step (defaults, f32): x tile 128x128 (64 KiB) +
y tile 128x128 (64 KiB) + o tile 128x128 (64 KiB) = 192 KiB, far below
the ~16 MiB VMEM of a TPU-v3 core — leaving room for the double
buffering the Mosaic pipeline inserts on real hardware.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def matmul(x, y, *, block_m=128, block_n=128, block_k=128, interpret=True):
    """Tiled matmul ``x @ y`` with f32 accumulation.

    Arbitrary (m, k) x (k, n) shapes; inputs are zero-padded up to tile
    multiples and the result is sliced back.

    Differentiable via an explicit VJP (Pallas kernels are not
    transposable by JAX AD): the cotangents are themselves computed with
    this kernel, so the backward pass also runs on the MXU tiling.
    """
    return _matmul_vjp(x, y, block_m, block_n, block_k, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _matmul_vjp(x, y, block_m, block_n, block_k, interpret):
    return _matmul_impl(x, y, block_m, block_n, block_k, interpret)


def _matmul_fwd(x, y, block_m, block_n, block_k, interpret):
    return _matmul_impl(x, y, block_m, block_n, block_k, interpret), (x, y)


def _matmul_bwd(block_m, block_n, block_k, interpret, res, g):
    x, y = res
    dx = _matmul_impl(g, y.T, block_m, block_n, block_k, interpret)
    dy = _matmul_impl(x.T, g, block_m, block_n, block_k, interpret)
    return dx.astype(x.dtype), dy.astype(y.dtype)


_matmul_vjp.defvjp(_matmul_fwd, _matmul_bwd)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def _matmul_impl(x, y, block_m=128, block_n=128, block_k=128, interpret=True):
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn, bk = (min(block_m, _ceil_to(m, 8)),
                  min(block_n, _ceil_to(n, 128)),
                  min(block_k, _ceil_to(k, 128)))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n].astype(x.dtype)
