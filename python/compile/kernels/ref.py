"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; the
pytest suite asserts allclose between kernel and oracle across shape and
dtype sweeps. These oracles are also what the L2 model uses when
``use_pallas=False``.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """f32-accumulating matmul oracle."""
    return jnp.dot(
        x.astype(jnp.float32), y.astype(jnp.float32), preferred_element_type=jnp.float32
    ).astype(x.dtype)


def combine_ref(a, b):
    """Gradient shard combine oracle: elementwise sum."""
    return a + b


def scaled_combine_ref(a, b, scale):
    """Combine then scale (ring-average step)."""
    return (a + b) * scale


def sgd_ref(params, grads, velocity, lr, momentum):
    """Momentum-SGD oracle: v' = mu*v + g ; p' = p - lr*v'."""
    v = momentum * velocity + grads
    return params - lr * v, v
