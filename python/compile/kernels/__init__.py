"""L1 Pallas kernels (interpret-mode for CPU-PJRT portability) and
their pure-jnp oracles."""

from .combine import combine, scaled_combine
from .matmul import matmul
from .sgd import sgd_update

__all__ = ["combine", "scaled_combine", "matmul", "sgd_update"]
