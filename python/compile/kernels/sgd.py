"""L1 Pallas kernel: fused momentum-SGD weight update.

The paper's future-work section (§4) plans weight-update sharding [22]:
computing the optimizer update on the reduce-scattered shards. This
kernel is the per-shard update — fused v' = mu*v + g; p' = p - lr*v' in
one pass over (8, 128) blocks, so it can run on a shard directly after
the reduce-scatter phase (see rust `trainer::optimizer` for the
L3-native twin used on the hot path).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _sgd_kernel(lr, momentum, p_ref, g_ref, v_ref, po_ref, vo_ref):
    v = momentum * v_ref[...] + g_ref[...]
    vo_ref[...] = v
    po_ref[...] = p_ref[...] - lr * v


@functools.partial(
    jax.jit, static_argnames=("lr", "momentum", "rows_per_block", "interpret")
)
def sgd_update(params, grads, velocity, *, lr, momentum, rows_per_block=8, interpret=True):
    """Fused momentum SGD over flat f32 vectors.

    Returns ``(new_params, new_velocity)``.
    """
    assert params.shape == grads.shape == velocity.shape and params.ndim == 1
    n = params.shape[0]
    block = rows_per_block * LANES
    npad = (n + block - 1) // block * block

    def prep(x):
        return jnp.pad(x, (0, npad - n)).reshape(-1, LANES)

    pp, gp, vp = prep(params), prep(grads), prep(velocity)
    rows = pp.shape[0]
    spec = pl.BlockSpec((rows_per_block, LANES), lambda i: (i, 0))
    kernel = functools.partial(_sgd_kernel, lr, momentum)
    po, vo = pl.pallas_call(
        kernel,
        grid=(rows // rows_per_block,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), params.dtype),
            jax.ShapeDtypeStruct((rows, LANES), params.dtype),
        ],
        interpret=interpret,
    )(pp, gp, vp)
    return po.reshape(-1)[:n], vo.reshape(-1)[:n]
