"""L1 Pallas kernel: gradient shard combine — the paper's compute
hot-spot.

Every step of a ring reduce-scatter sums an arriving gradient chunk
into the local accumulator (paper §2.1). This kernel is that summation,
streamed in (8, 128) VPU-lane-shaped blocks: 8 sublanes x 128 lanes is
the natural f32 vector-register tile of a TPU core, so consecutive grid
steps walk the chunk in exactly the layout the VPU consumes.

Wrapper handles arbitrary flat lengths by padding to a whole number of
blocks. VMEM per grid step: 3 blocks x 4 KiB = 12 KiB (with default
``rows_per_block=8``) — the kernel is memory-bound by design, matching
the roofline of gradient summation on any hardware.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _combine_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _scaled_combine_kernel(scale, a_ref, b_ref, o_ref):
    o_ref[...] = (a_ref[...] + b_ref[...]) * scale


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def combine(a, b, *, rows_per_block=8, interpret=True):
    """Elementwise ``a + b`` over flat f32 vectors."""
    return _run(_combine_kernel, a, b, rows_per_block, interpret)


@functools.partial(jax.jit, static_argnames=("scale", "rows_per_block", "interpret"))
def scaled_combine(a, b, *, scale, rows_per_block=8, interpret=True):
    """``(a + b) * scale`` — the ring-average step (sum then divide by
    the worker count folds into the final gather)."""
    kernel = functools.partial(_scaled_combine_kernel, scale)
    return _run(kernel, a, b, rows_per_block, interpret)


def _run(kernel, a, b, rows_per_block, interpret):
    assert a.shape == b.shape and a.ndim == 1, "flat vectors only"
    n = a.shape[0]
    block = rows_per_block * LANES
    npad = (n + block - 1) // block * block
    ap = jnp.pad(a, (0, npad - n)).reshape(-1, LANES)
    bp = jnp.pad(b, (0, npad - n)).reshape(-1, LANES)
    rows = ap.shape[0]
    out = pl.pallas_call(
        kernel,
        grid=(rows // rows_per_block,),
        in_specs=[
            pl.BlockSpec((rows_per_block, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_block, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), a.dtype),
        interpret=interpret,
    )(ap, bp)
    return out.reshape(-1)[:n]
