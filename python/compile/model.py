"""L2: JAX transformer language model — the data-parallel training
workload whose gradients the L3 coordinator allreduces.

The paper trains ResNet-50 and BERT; the reproduction's end-to-end
driver trains this decoder-only transformer (BERT-scale configs are
provided; the perf model covers the paper-scale payloads). The model is
deliberately written over *flat* parameter vectors at the AOT boundary:
``train_step(flat_params, tokens) -> (loss, flat_grads)`` so the Rust
side can treat gradients as the single contiguous payload the allreduce
schedules shard (exactly how the paper's gradient summation sees them).

MLP matmuls route through the L1 Pallas matmul kernel when
``config.use_pallas`` — this is the L1-in-L2 composition that makes the
Pallas kernel part of the exported HLO artifact.
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul as pallas_matmul


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    batch: int
    use_pallas: bool
    lr: float = 0.05
    momentum: float = 0.9

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


#: Exported configurations. `tiny` routes its MLP through the Pallas
#: matmul kernel (slow under interpret mode, but proves the L1->L2->L3
#: composition end to end); `small` is the end-to-end training example;
#: `base` is a ~100M-parameter GPT-2-small-scale config for paper-scale
#: experiments (export it with `python -m compile.aot --configs base`).
CONFIGS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=2,
                        seq_len=32, batch=4, use_pallas=True),
    "small": ModelConfig("small", vocab=1024, d_model=256, n_layers=4, n_heads=4,
                         seq_len=64, batch=4, use_pallas=False),
    "base": ModelConfig("base", vocab=8192, d_model=768, n_layers=12, n_heads=12,
                        seq_len=128, batch=2, use_pallas=False),
}


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat packing layout."""
    d, f = cfg.d_model, cfg.d_ff
    spec = [("embed", (cfg.vocab, d)), ("pos", (cfg.seq_len, d))]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}.ln1_scale", (d,)),
            (f"l{i}.ln1_bias", (d,)),
            (f"l{i}.wq", (d, d)),
            (f"l{i}.wk", (d, d)),
            (f"l{i}.wv", (d, d)),
            (f"l{i}.wo", (d, d)),
            (f"l{i}.ln2_scale", (d,)),
            (f"l{i}.ln2_bias", (d,)),
            (f"l{i}.w1", (d, f)),
            (f"l{i}.b1", (f,)),
            (f"l{i}.w2", (f, d)),
            (f"l{i}.b2", (d,)),
        ]
    spec += [("lnf_scale", (d,)), ("lnf_bias", (d,))]
    return spec


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_spec(cfg))


def unpack(cfg: ModelConfig, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Flat f32 vector -> named parameter dict (zero-copy reshapes)."""
    params = {}
    off = 0
    for name, shape in param_spec(cfg):
        n = 1
        for s in shape:
            n *= s
        params[name] = flat[off : off + n].reshape(shape)
        off += n
    assert off == flat.shape[0], f"flat size {flat.shape[0]} != spec {off}"
    return params


def pack(cfg: ModelConfig, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Named parameter dict -> flat f32 vector."""
    return jnp.concatenate([params[name].reshape(-1) for name, _ in param_spec(cfg)])


def init_params(cfg: ModelConfig, seed: int) -> jnp.ndarray:
    """Scaled-normal initialisation, returned flat."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_scale",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_bias", ".b1", ".b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 0.02 if name in ("embed", "pos") else 1.0 / jnp.sqrt(fan_in)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return pack(cfg, params)


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _mm(cfg: ModelConfig, a2d, w):
    """2-D matmul through the Pallas kernel or jnp (the oracle)."""
    if cfg.use_pallas:
        return pallas_matmul(a2d, w)
    return a2d @ w


def _attention(cfg: ModelConfig, p, i, x):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def proj(w):
        return (x.reshape(b * s, d) @ w).reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    q = proj(p[f"l{i}.wq"])
    k = proj(p[f"l{i}.wk"])
    v = proj(p[f"l{i}.wv"])
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(hd).astype(x.dtype)
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask, logits, -1e9)
    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b * s, d)
    return (out @ p[f"l{i}.wo"]).reshape(b, s, d)


def _mlp(cfg: ModelConfig, p, i, x):
    b, s, d = x.shape
    h = _mm(cfg, x.reshape(b * s, d), p[f"l{i}.w1"]) + p[f"l{i}.b1"]
    h = jax.nn.gelu(h)
    out = _mm(cfg, h, p[f"l{i}.w2"]) + p[f"l{i}.b2"]
    return out.reshape(b, s, d)


def forward(cfg: ModelConfig, params: Dict[str, jnp.ndarray], tokens: jnp.ndarray):
    """tokens [B, S] int32 -> logits [B, S, V]."""
    p = params
    x = p["embed"][tokens] + p["pos"][None, :, :]
    for i in range(cfg.n_layers):
        x = x + _attention(cfg, p, i, _layer_norm(x, p[f"l{i}.ln1_scale"], p[f"l{i}.ln1_bias"]))
        x = x + _mlp(cfg, p, i, _layer_norm(x, p[f"l{i}.ln2_scale"], p[f"l{i}.ln2_bias"]))
    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    b, s, d = x.shape
    return (x.reshape(b * s, d) @ p["embed"].T).reshape(b, s, cfg.vocab)


def loss_fn(cfg: ModelConfig, flat_params: jnp.ndarray, tokens: jnp.ndarray):
    """Next-token cross-entropy over [B, S] int32 tokens."""
    params = unpack(cfg, flat_params)
    logits = forward(cfg, params, tokens)
    targets = tokens[:, 1:]
    preds = logits[:, :-1, :]
    logp = jax.nn.log_softmax(preds, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).squeeze(-1)
    return nll.mean()


def train_step(cfg: ModelConfig):
    """Returns fn(flat_params, tokens) -> (loss, flat_grads)."""

    def step(flat_params, tokens):
        loss, grads = jax.value_and_grad(lambda fp: loss_fn(cfg, fp, tokens))(flat_params)
        return loss, grads

    return step


def sgd_step(cfg: ModelConfig):
    """Returns fn(flat_params, flat_grads, velocity) ->
    (new_params, new_velocity), using the L1 fused kernel."""
    from .kernels.sgd import sgd_update

    def step(flat_params, flat_grads, velocity):
        return sgd_update(
            flat_params, flat_grads, velocity, lr=cfg.lr, momentum=cfg.momentum
        )

    return step
