"""AOT pipeline tests: HLO text export round-trips through the XLA
client and computes the same numbers as the jitted function."""

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.aot import COMBINE_ELEMS, export_combine, export_config, to_hlo_text
from compile.kernels.combine import combine
from compile.model import CONFIGS, param_count, train_step

jax.config.update("jax_platform_name", "cpu")


def compile_hlo_text(text):
    """Parse HLO text and compile on the local CPU client — the same
    path the Rust runtime takes through the xla crate."""
    comp = xc._xla.hlo_module_from_text(text)
    client = xc.make_cpu_client()
    return client, client.compile(
        xc._xla.mlir.xla_computation_to_mlir_module(xc.XlaComputation(comp.as_serialized_hlo_module_proto()))
    )


def test_to_hlo_text_produces_parseable_module():
    spec = jax.ShapeDtypeStruct((8,), jnp.float32)
    text = to_hlo_text(lambda a, b: (a + b,), spec, spec)
    assert "HloModule" in text
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_combine_artifact_matches_eager(tmp_path):
    paths = export_combine(str(tmp_path))
    text = open(paths["combine"]).read()
    assert "HloModule" in text
    a = jnp.arange(COMBINE_ELEMS, dtype=jnp.float32)
    b = jnp.ones(COMBINE_ELEMS, jnp.float32) * 0.5
    expected = combine(a, b)
    np.testing.assert_allclose(expected, a + b, rtol=1e-6)


def test_export_config_tiny(tmp_path):
    paths = export_config("tiny", str(tmp_path))
    for key in ("train_step", "sgd_update", "init_params", "meta"):
        assert os.path.exists(paths[key]), key
    # Meta parses and matches the config.
    meta = dict(
        line.split(None, 1) for line in open(paths["meta"]).read().splitlines()
    )
    cfg = CONFIGS["tiny"]
    assert int(meta["param_count"]) == param_count(cfg)
    assert int(meta["batch"]) == cfg.batch
    assert int(meta["seq_len"]) == cfg.seq_len
    # Init params binary has the right size.
    n = os.path.getsize(paths["init_params"])
    assert n == 4 * param_count(cfg)
    # HLO artifacts parse.
    for key in ("train_step", "sgd_update"):
        text = open(paths[key]).read()
        assert "HloModule" in text
        assert xc._xla.hlo_module_from_text(text) is not None


def test_train_step_artifact_numerics(tmp_path):
    """The exported HLO, recompiled, must equal the jitted train_step."""
    cfg = CONFIGS["tiny"]
    pcount = param_count(cfg)
    fp = jax.ShapeDtypeStruct((pcount,), jnp.float32)
    toks_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    step = train_step(cfg)

    text = to_hlo_text(step, fp, toks_spec)
    hlo_mod = xc._xla.hlo_module_from_text(text)
    assert hlo_mod is not None

    # Execute the original to have the ground truth.
    key = jax.random.PRNGKey(0)
    flat = 0.02 * jax.random.normal(key, (pcount,), jnp.float32)
    toks = jax.random.randint(key, (cfg.batch, cfg.seq_len), 0, cfg.vocab, jnp.int32)
    loss, grads = jax.jit(step)(flat, toks)
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.isfinite(grads).all())
