"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is
the core correctness signal for everything the AOT artifacts contain.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.combine import combine, scaled_combine
from compile.kernels.matmul import matmul
from compile.kernels.ref import combine_ref, matmul_ref, scaled_combine_ref, sgd_ref
from compile.kernels.sgd import sgd_update

jax.config.update("jax_platform_name", "cpu")


def rnd(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------- matmul


@pytest.mark.parametrize(
    "m,k,n",
    [(8, 8, 128), (128, 128, 128), (256, 128, 384), (100, 70, 50), (1, 1, 1), (17, 129, 33)],
)
def test_matmul_matches_ref_shapes(m, k, n):
    x = rnd(1, (m, k))
    y = rnd(2, (k, n))
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = rnd(3, (64, 64), dtype)
    y = rnd(4, (64, 64), dtype)
    got = matmul(x, y)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        got.astype(jnp.float32),
        matmul_ref(x, y).astype(jnp.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
    )


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31),
)
def test_matmul_hypothesis_sweep(m, k, n, seed):
    x = rnd(seed, (m, k))
    y = rnd(seed + 1, (k, n))
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_small_blocks():
    # Explicit non-default tiling exercises the K-loop accumulation.
    x = rnd(5, (64, 96))
    y = rnd(6, (96, 48))
    got = matmul(x, y, block_m=16, block_n=16, block_k=32)
    np.testing.assert_allclose(got, matmul_ref(x, y), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- combine


@pytest.mark.parametrize("n", [1, 5, 1024, 1023, 8 * 128, 8 * 128 + 1, 1 << 16])
def test_combine_matches_ref(n):
    a = rnd(7, (n,))
    b = rnd(8, (n,))
    np.testing.assert_allclose(combine(a, b), combine_ref(a, b), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 2**31))
def test_combine_hypothesis_sweep(n, seed):
    a = rnd(seed, (n,))
    b = rnd(seed + 1, (n,))
    np.testing.assert_allclose(combine(a, b), combine_ref(a, b), rtol=1e-6)


def test_scaled_combine():
    a = rnd(9, (1000,))
    b = rnd(10, (1000,))
    np.testing.assert_allclose(
        scaled_combine(a, b, scale=0.25), scaled_combine_ref(a, b, 0.25), rtol=1e-6
    )


# ------------------------------------------------------------------ sgd


@pytest.mark.parametrize("n", [1, 100, 8 * 128, 5000])
def test_sgd_matches_ref(n):
    p = rnd(11, (n,))
    g = rnd(12, (n,))
    v = rnd(13, (n,))
    got_p, got_v = sgd_update(p, g, v, lr=0.1, momentum=0.9)
    ref_p, ref_v = sgd_ref(p, g, v, 0.1, 0.9)
    np.testing.assert_allclose(got_p, ref_p, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_v, ref_v, rtol=1e-5, atol=1e-6)


def test_sgd_zero_momentum_is_plain_sgd():
    p = rnd(14, (512,))
    g = rnd(15, (512,))
    v = jnp.zeros(512)
    got_p, got_v = sgd_update(p, g, v, lr=0.5, momentum=0.0)
    np.testing.assert_allclose(got_p, p - 0.5 * g, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_v, g, rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 3000),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.99),
    seed=st.integers(0, 2**31),
)
def test_sgd_hypothesis_sweep(n, lr, mu, seed):
    p = rnd(seed, (n,))
    g = rnd(seed + 1, (n,))
    v = rnd(seed + 2, (n,))
    got_p, got_v = sgd_update(p, g, v, lr=lr, momentum=mu)
    ref_p, ref_v = sgd_ref(p, g, v, lr, mu)
    np.testing.assert_allclose(got_p, ref_p, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_v, ref_v, rtol=1e-5, atol=1e-6)
