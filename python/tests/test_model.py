"""L2 model tests: shapes, packing round-trip, gradient equivalence
between the Pallas and jnp paths, and optimization sanity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    CONFIGS,
    forward,
    init_params,
    loss_fn,
    pack,
    param_count,
    param_spec,
    sgd_step,
    train_step,
    unpack,
)

jax.config.update("jax_platform_name", "cpu")

TINY = CONFIGS["tiny"]


def tokens_for(cfg, seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (cfg.batch, cfg.seq_len), 0, cfg.vocab, jnp.int32
    )


def test_param_count_tiny():
    # embed 256*64 + pos 32*64 + 2 layers * (4*64^2 attn + 2*64*256 +
    # 256 + 64 mlp + 4*64 ln) + final ln.
    assert param_count(TINY) == sum(
        int(np.prod(s)) for _, s in param_spec(TINY)
    )
    assert 100_000 < param_count(TINY) < 1_000_000


def test_pack_unpack_roundtrip():
    flat = init_params(TINY, seed=1)
    assert flat.shape == (param_count(TINY),)
    params = unpack(TINY, flat)
    flat2 = pack(TINY, params)
    np.testing.assert_array_equal(flat, flat2)


def test_forward_shapes():
    flat = init_params(TINY, seed=2)
    params = unpack(TINY, flat)
    toks = tokens_for(TINY)
    logits = forward(TINY, params, toks)
    assert logits.shape == (TINY.batch, TINY.seq_len, TINY.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_loss_finite_and_near_uniform_at_init():
    flat = init_params(TINY, seed=3)
    loss = loss_fn(TINY, flat, tokens_for(TINY))
    # Untrained next-token loss should be close to ln(vocab).
    assert abs(float(loss) - np.log(TINY.vocab)) < 1.0


def test_pallas_and_jnp_paths_agree():
    # The tiny config uses the Pallas MLP matmul; flipping the flag must
    # not change the math.
    flat = init_params(TINY, seed=4)
    toks = tokens_for(TINY)
    cfg_jnp = dataclasses.replace(TINY, use_pallas=False)
    loss_pallas, grads_pallas = train_step(TINY)(flat, toks)
    loss_jnp, grads_jnp = train_step(cfg_jnp)(flat, toks)
    np.testing.assert_allclose(float(loss_pallas), float(loss_jnp), rtol=1e-5)
    np.testing.assert_allclose(grads_pallas, grads_jnp, rtol=2e-4, atol=2e-6)


def test_grads_nonzero_everywhere():
    flat = init_params(TINY, seed=5)
    _, grads = train_step(TINY)(flat, tokens_for(TINY))
    assert grads.shape == flat.shape
    # Every parameter tensor should receive some gradient signal.
    g = unpack(TINY, grads)
    for name, _ in param_spec(TINY):
        assert float(jnp.abs(g[name]).max()) > 0.0, name


def test_sgd_training_reduces_loss():
    # Overfit a single tiny batch for a few steps.
    cfg = TINY
    flat = init_params(cfg, seed=6)
    vel = jnp.zeros_like(flat)
    toks = tokens_for(cfg, seed=7)
    step = jax.jit(train_step(cfg))
    opt = jax.jit(sgd_step(cfg))
    loss0, grads = step(flat, toks)
    for _ in range(10):
        flat, vel = opt(flat, grads, vel)
        loss, grads = step(flat, toks)
    assert float(loss) < float(loss0) * 0.9, (float(loss0), float(loss))


@pytest.mark.parametrize("name", ["tiny", "small"])
def test_exported_configs_valid(name):
    cfg = CONFIGS[name]
    assert cfg.d_model % cfg.n_heads == 0
    assert param_count(cfg) > 0


def test_base_config_is_paper_scale():
    # ~100M parameters (GPT-2-small scale), per the repo mandate.
    assert param_count(CONFIGS["base"]) > 80_000_000
